//! CacheHash (paper §4): separate chaining with the first link inlined
//! into the bucket as a big atomic — generic over key and value types.
//!
//! Each bucket is a big atomic [`Link<K, V>`] = (key, value, next+flag):
//! the common case (load factor one, most chains of length ≤ 1) touches
//! a single cache line and zero pointers — the paper's motivating win.
//! Chain nodes beyond the first are immutable heap links; every mutation
//! happens by a single `compare_exchange` on the bucket head (inserts
//! push the old head out to the heap; deletes path-copy the prefix), so
//! linearizability reduces to the big atomic's. Failed head CASes feed
//! their *witness* back into the retry — the bucket is re-read zero
//! extra times no matter how contended — and `insert` additionally
//! remembers which (immutable) chain it already proved duplicate-free,
//! so a retry whose witnessed chain pointer is unchanged skips the
//! second chain walk entirely. Retries back off through the adaptive
//! `util::backoff::Backoff`.
//!
//! Chain traversals are unbounded, so reclamation needs a
//! *region-grained* scheme ([`RegionSmr`]): epoch-based by default (§4:
//! "We use epoch-based memory management to protect the links"), with
//! the scheme parameter `S` selecting the epoch ordering policy
//! (`Epoch<Fenced>` vs `Epoch<SeqCstEverywhere>` — the reclamation leg
//! of the ordering ablation). Hazard pointers cannot satisfy the region
//! contract and are rejected at the type level — see `smr`'s module
//! docs for why.

use super::{bucket_for, table_capacity, ConcurrentMap};
use crate::atomics::{AtomicValue, BigAtomic};
use crate::smr::{Epoch, RegionSmr};
use crate::util::backoff::snooze_lazy;
use crate::util::CachePadded;

/// The inlined first link: key, value, and a tagged next pointer.
/// Bit 0 of `next` is the occupied flag — `0x0` = empty bucket,
/// `0x1` = single inline entry (null next), `ptr|1` = inline entry with
/// a chain. "Null and empty have distinct meanings" (§4).
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Debug, Default)]
pub struct Link<K: AtomicValue, V: AtomicValue> {
    pub key: K,
    pub value: V,
    pub next: u64,
}

// SAFETY: repr(C) of AtomicValue fields and a u64 — all 8-byte aligned,
// sizes multiples of 8, no padding, bitwise PartialEq.
unsafe impl<K: AtomicValue, V: AtomicValue> AtomicValue for Link<K, V> {}

/// The classic single-word instantiation (§5.2's 8-byte keys/values).
pub type LinkVal = Link<u64, u64>;

impl Link<u64, u64> {
    pub const EMPTY: LinkVal = LinkVal {
        key: 0,
        value: 0,
        next: 0,
    };
}

const OCCUPIED: u64 = 1;

impl<K: AtomicValue, V: AtomicValue> Link<K, V> {
    /// An unoccupied bucket value.
    #[inline]
    pub fn empty() -> Self {
        Self::default()
    }

    #[inline]
    fn occupied(&self) -> bool {
        self.next & OCCUPIED == OCCUPIED
    }

    #[inline]
    fn next_ptr(&self) -> *mut ChainNode<K, V> {
        (self.next & !OCCUPIED) as *mut ChainNode<K, V>
    }

    #[inline]
    fn with_chain(key: K, value: V, chain: *mut ChainNode<K, V>) -> Self {
        Link {
            key,
            value,
            next: (chain as u64) | OCCUPIED,
        }
    }
}

/// Immutable-after-publish chain link.
struct ChainNode<K, V> {
    key: K,
    value: V,
    next: *mut ChainNode<K, V>,
}

pub struct CacheHash<A, K = u64, V = u64, S = Epoch>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    buckets: Box<[CachePadded<A>]>,
    name: &'static str,
    _kv: std::marker::PhantomData<(Link<K, V>, fn() -> S)>,
}

// SAFETY: buckets are Sync big atomics; chain nodes are immutable and
// region-protected.
unsafe impl<A, K, V, S> Send for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
}
unsafe impl<A, K, V, S> Sync for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
}

impl<A, K, V, S> CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    /// A table with capacity for ~`n` entries at load factor one.
    pub fn new(n: usize) -> Self {
        let cap = table_capacity(n);
        Self {
            buckets: (0..cap)
                .map(|_| CachePadded::new(A::new(Link::empty())))
                .collect(),
            name: A::name(),
            _kv: std::marker::PhantomData,
        }
    }

    #[inline]
    fn bucket(&self, key: &K) -> &A {
        &self.buckets[bucket_for(key, self.buckets.len())]
    }

    /// Walk the (immutable) chain for `key`.
    #[inline]
    fn chain_find(mut p: *mut ChainNode<K, V>, key: &K) -> Option<V> {
        while !p.is_null() {
            // SAFETY: region-pinned by caller; nodes retired only after
            // being unlinked by a bucket CAS that happened-after our
            // head load.
            let n = unsafe { &*p };
            if n.key == *key {
                return Some(n.value);
            }
            p = n.next;
        }
        None
    }

    pub fn capacity(&self) -> usize {
        self.buckets.len()
    }
}

impl<A, K, V, S> ConcurrentMap<K, V> for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    fn find(&self, key: K) -> Option<V> {
        let _g = S::pin();
        let head = self.bucket(&key).load();
        if !head.occupied() {
            return None;
        }
        if head.key == key {
            return Some(head.value); // the inlined fast path
        }
        Self::chain_find(head.next_ptr(), &key)
    }

    fn insert(&self, key: K, value: V) -> bool {
        let _g = S::pin();
        let bucket = self.bucket(&key);
        let mut head = bucket.load();
        // The chain pointer we last walked and proved free of `key`.
        // Chain nodes are immutable after publish and we hold the region
        // pin for the whole operation, so no node reachable from a head
        // we read can be freed (or its address reused) before we return
        // — pointer equality therefore implies the entire chain is
        // unchanged, and a witness-fed retry whose chain pointer matches
        // skips the second walk (the duplicate check cost under
        // contention).
        let mut searched: Option<*mut ChainNode<K, V>> = None;
        // Lazy: an uncontended insert pays no backoff/TLS cost.
        let mut bo = None;
        loop {
            if !head.occupied() {
                // Empty bucket: install inline. On failure the witness
                // is the new head — no re-load.
                match bucket.compare_exchange(
                    head,
                    Link::with_chain(key, value, std::ptr::null_mut()),
                ) {
                    Ok(_) => return true,
                    Err(w) => {
                        head = w;
                        snooze_lazy(&mut bo);
                        continue;
                    }
                }
            }
            if head.key == key {
                return false;
            }
            let chain = head.next_ptr();
            if searched != Some(chain) {
                if Self::chain_find(chain, &key).is_some() {
                    return false;
                }
                searched = Some(chain);
            }
            // Push-front: the new pair goes inline; the old inline pair
            // moves out to a heap link pointing at the existing chain.
            let spill = Box::into_raw(Box::new(ChainNode {
                key: head.key,
                value: head.value,
                next: chain,
            }));
            match bucket.compare_exchange(head, Link::with_chain(key, value, spill)) {
                Ok(_) => return true,
                Err(w) => {
                    // SAFETY: never published.
                    drop(unsafe { Box::from_raw(spill) });
                    head = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn remove(&self, key: K) -> bool {
        let _g = S::pin();
        let bucket = self.bucket(&key);
        let mut head = bucket.load();
        // Lazy: an uncontended remove pays no backoff/TLS cost.
        let mut bo = None;
        loop {
            if !head.occupied() {
                return false;
            }
            if head.key == key {
                let p = head.next_ptr();
                if p.is_null() {
                    // Single inline entry -> empty.
                    match bucket.compare_exchange(head, Link::empty()) {
                        Ok(_) => return true,
                        Err(w) => {
                            head = w;
                            snooze_lazy(&mut bo);
                            continue;
                        }
                    }
                }
                // Promote the first chain node inline.
                // SAFETY: region-pinned, reachable.
                let n = unsafe { &*p };
                let promoted = Link::with_chain(n.key, n.value, n.next);
                match bucket.compare_exchange(head, promoted) {
                    Ok(_) => {
                        // SAFETY: p unlinked by the successful CAS.
                        unsafe { S::retire_box(p) };
                        return true;
                    }
                    Err(w) => {
                        head = w;
                        snooze_lazy(&mut bo);
                        continue;
                    }
                }
            }
            // Delete inside the chain: path-copy the prefix (§4).
            let mut prefix: Vec<(K, V)> = Vec::new();
            let mut p = head.next_ptr();
            let mut found = false;
            let mut suffix: *mut ChainNode<K, V> = std::ptr::null_mut();
            while !p.is_null() {
                // SAFETY: region-pinned traversal.
                let n = unsafe { &*p };
                if n.key == key {
                    found = true;
                    suffix = n.next;
                    break;
                }
                prefix.push((n.key, n.value));
                p = n.next;
            }
            if !found {
                return false;
            }
            let victim = p;
            // Rebuild the prefix copies back-to-front onto the suffix.
            let mut new_chain = suffix;
            for &(k, v) in prefix.iter().rev() {
                new_chain = Box::into_raw(Box::new(ChainNode {
                    key: k,
                    value: v,
                    next: new_chain,
                }));
            }
            let new_head = Link::with_chain(head.key, head.value, new_chain);
            match bucket.compare_exchange(head, new_head) {
                Ok(_) => {
                    // Retire the victim and the replaced original prefix.
                    // SAFETY: all unlinked by the successful CAS.
                    unsafe {
                        S::retire_box(victim);
                        let mut q = head.next_ptr();
                        while q != victim {
                            let nx = (*q).next;
                            S::retire_box(q);
                            q = nx;
                        }
                    }
                    return true;
                }
                Err(w) => {
                    // CAS failed: free the unpublished copies, continue
                    // from the witnessed head.
                    let mut q = new_chain;
                    while q != suffix {
                        // SAFETY: never published.
                        let b = unsafe { Box::from_raw(q) };
                        q = b.next;
                    }
                    head = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn map_name(&self) -> &'static str {
        self.name
    }
}

impl<A, K, V, S> Drop for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    fn drop(&mut self) {
        // Exclusive: free all chains directly.
        for b in self.buckets.iter() {
            let head = b.load();
            if head.occupied() {
                let mut p = head.next_ptr();
                while !p.is_null() {
                    // SAFETY: exclusive in Drop.
                    let n = unsafe { Box::from_raw(p) };
                    p = n.next;
                }
            }
        }
        S::flush_thread_bag();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::{CachedMemEff, SeqLock, Words};
    use std::sync::Arc;

    fn basic<A: BigAtomic<LinkVal>>() {
        let t: CacheHash<A> = CacheHash::new(64);
        assert_eq!(t.find(1), None);
        assert!(t.insert(1, 10));
        assert!(!t.insert(1, 11), "duplicate insert must fail");
        assert_eq!(t.find(1), Some(10));
        assert!(t.remove(1));
        assert!(!t.remove(1));
        assert_eq!(t.find(1), None);
    }

    #[test]
    fn test_basic_seqlock() {
        basic::<SeqLock<LinkVal>>();
    }

    #[test]
    fn test_basic_memeff() {
        basic::<CachedMemEff<LinkVal>>();
    }

    #[test]
    fn test_explicit_epoch_policy_instantiations() {
        // The table is generic over the epoch ordering policy: the
        // fenced default and the blanket-SeqCst audit instantiation must
        // behave identically (the smr ablation compares them).
        use crate::smr::Epoch;
        use crate::util::ordering::{Fenced, SeqCstEverywhere};
        fn run<S: crate::smr::RegionSmr>() {
            let t: CacheHash<SeqLock<LinkVal>, u64, u64, S> = CacheHash::new(8);
            for k in 0..64u64 {
                assert!(t.insert(k, k + 1));
            }
            for k in (0..64u64).step_by(2) {
                assert!(t.remove(k));
            }
            for k in 0..64u64 {
                let want = if k % 2 == 0 { None } else { Some(k + 1) };
                assert_eq!(t.find(k), want);
            }
        }
        run::<Epoch<Fenced>>();
        run::<Epoch<SeqCstEverywhere>>();
    }

    #[test]
    fn test_generic_multiword_keys_and_values() {
        // The §5.3 arbitrary-length instantiation: 4-word keys, 4-word
        // values, including forced collisions in a tiny table.
        type K = Words<4>;
        type V = Words<4>;
        let t: CacheHash<CachedMemEff<Link<K, V>>, K, V> = CacheHash::new(4);
        for i in 0..200u64 {
            assert!(t.insert(Words([i, i ^ 7, 0, i]), Words([i; 4])));
        }
        for i in 0..200u64 {
            assert_eq!(t.find(Words([i, i ^ 7, 0, i])), Some(Words([i; 4])));
        }
        assert_eq!(t.find(Words([1, 1, 1, 1])), None);
        for i in (0..200u64).step_by(3) {
            assert!(t.remove(Words([i, i ^ 7, 0, i])));
        }
        for i in 0..200u64 {
            let want = if i % 3 == 0 { None } else { Some(Words([i; 4])) };
            assert_eq!(t.find(Words([i, i ^ 7, 0, i])), want);
        }
    }

    #[test]
    fn test_mixed_width_key_value() {
        // Asymmetric instantiation: wide key, single-word value.
        type K = Words<2>;
        let t: CacheHash<SeqLock<Link<K, u64>>, K, u64> = CacheHash::new(16);
        assert!(t.insert(Words([7, 8]), 99));
        assert_eq!(t.find(Words([7, 8])), Some(99));
        assert_eq!(t.find(Words([8, 7])), None);
        assert!(t.remove(Words([7, 8])));
    }

    #[test]
    fn test_chains_beyond_one_bucket() {
        // Tiny table forces chains; all pairs must survive.
        let t: CacheHash<SeqLock<LinkVal>> = CacheHash::new(2);
        for k in 0..100u64 {
            assert!(t.insert(k, k * 7));
        }
        for k in 0..100u64 {
            assert_eq!(t.find(k), Some(k * 7), "key {k}");
        }
        // Delete interior/head/tail mixes.
        for k in (0..100u64).step_by(3) {
            assert!(t.remove(k));
        }
        for k in 0..100u64 {
            let want = if k % 3 == 0 { None } else { Some(k * 7) };
            assert_eq!(t.find(k), want, "key {k}");
        }
    }

    #[test]
    fn test_concurrent_disjoint_keys() {
        let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(1024));
        let threads = 4;
        let per = 2_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|tix| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let base = tix as u64 * 1_000_000;
                    for i in 0..per {
                        assert!(t.insert(base + i, i));
                    }
                    for i in 0..per {
                        assert_eq!(t.find(base + i), Some(i));
                    }
                    for i in (0..per).step_by(2) {
                        assert!(t.remove(base + i));
                    }
                    for i in 0..per {
                        let want = if i % 2 == 0 { None } else { Some(i) };
                        assert_eq!(t.find(base + i), want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn test_concurrent_duplicate_inserts_exactly_one_winner() {
        // Both threads race to insert the same keys into a 2-bucket
        // table (long chains force the duplicate check through the
        // witness-fed retry with the searched-chain skip): every key
        // must be inserted exactly once.
        let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(2));
        let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let t = Arc::clone(&t);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    for k in 0..500u64 {
                        if t.insert(k, k + 1) {
                            wins.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(wins.load(std::sync::atomic::Ordering::SeqCst), 500);
        for k in 0..500u64 {
            assert_eq!(t.find(k), Some(k + 1), "key {k}");
        }
    }

    #[test]
    fn test_concurrent_same_key_contention() {
        // Insert/remove storms on one key: at the end, state must be
        // consistent with the net count of successful ops.
        let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(8));
        let inserts = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let removes = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..4)
            .map(|tix| {
                let t = Arc::clone(&t);
                let inserts = Arc::clone(&inserts);
                let removes = Arc::clone(&removes);
                std::thread::spawn(move || {
                    for i in 0..4_000u64 {
                        if (i + tix) % 2 == 0 {
                            if t.insert(42, i) {
                                inserts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        } else if t.remove(42) {
                            removes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let ins = inserts.load(std::sync::atomic::Ordering::SeqCst);
        let rem = removes.load(std::sync::atomic::Ordering::SeqCst);
        let present = t.find(42).is_some() as u64;
        assert_eq!(ins, rem + present, "ins={ins} rem={rem} present={present}");
    }
}
