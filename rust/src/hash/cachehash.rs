//! CacheHash (paper §4): separate chaining with the first link inlined
//! into the bucket as a big atomic — generic over key and value types,
//! and **growable online** (epoch-protected incremental resize).
//!
//! Each bucket is a big atomic [`Link<K, V>`] = (key, value, next+tags):
//! the common case (load factor one, most chains of length ≤ 1) touches
//! a single cache line and zero pointers — the paper's motivating win.
//! Chain nodes beyond the first are immutable heap links; every mutation
//! happens by a single `compare_exchange` on the bucket head (inserts
//! push the old head out to the heap; deletes path-copy the prefix), so
//! linearizability reduces to the big atomic's. Failed head CASes feed
//! their *witness* back into the retry — the bucket is re-read zero
//! extra times no matter how contended — and `insert` additionally
//! remembers which (immutable) chain it already proved duplicate-free,
//! so a retry whose witnessed chain pointer is unchanged skips the
//! second chain walk entirely. Retries back off through the adaptive
//! `util::backoff::Backoff`.
//!
//! ## Online resize
//!
//! The table is a generation chain: the live generation is published
//! through `root`, and a growth (triggered when a per-stripe occupancy
//! estimate crosses [`GROW_LOAD_FACTOR`]) publishes a
//! [`ResizeState`](super::ResizeState) descriptor — (old table, new
//! table, stripe cursor) — through a `SeqLock` big atomic.  Every
//! *update* entering the map claims one stripe of source buckets with
//! the witnessing `compare_exchange` on the cursor and migrates it:
//!
//! 1. **seal** — CAS the source bucket to its FROZEN image (same key /
//!    value / chain, FORWARDED tag set).  The seal winner is the
//!    *preferred* copier — but not a single point of failure: updates
//!    that land on a FROZEN bucket wait a bounded number of beats and
//!    then re-run the copy themselves (takeover), so a copier that
//!    stalls or dies delays the bucket, never wedges it.  `find`s read
//!    the frozen content in place — the frozen image *is* the current
//!    state, because no mutation of those keys can complete before the
//!    DONE transition.
//! 2. **copy** — re-hash the inlined pair and every chain node into the
//!    destination (fresh allocations; insert-if-absent, so concurrent
//!    copiers of the same immutable image are idempotent). Copiers
//!    announce themselves through the [`census`](super::census)
//!    (announce → re-validate FROZEN → copy, RAII-cleared on unwind).
//! 3. **CLOSING** — CAS FROZEN → the same image with the CLOSING mark:
//!    no new copier joins past this point (the census validation
//!    rejects it), and the publisher waits until no rival copier is
//!    still announced — the fence that keeps every destination write
//!    pre-DONE.
//! 4. **DONE** — CAS CLOSING → the empty-forwarded sentinel.  From this
//!    (big-atomic, hence linearizable) transition on, readers and
//!    updaters fall through old → new, and the drained chain is retired
//!    through the epoch scheme — by the unique transition winner.
//!
//! `find` therefore stays lock-free throughout: it never helps, never
//! waits, and crosses generations only over DONE seal marks.  The
//! drained table itself is retired with `S::retire_box` once every
//! bucket is DONE — `RegionSmr` guarantees a pinned reader mid-fall-
//! through cannot see a freed table.
//!
//! Chain traversals are unbounded, so reclamation needs a
//! *region-grained* scheme ([`RegionSmr`]): epoch-based by default (§4:
//! "We use epoch-based memory management to protect the links"), with
//! the scheme parameter `S` selecting the epoch ordering policy
//! (`Epoch<Fenced>` vs `Epoch<SeqCstEverywhere>` — the reclamation leg
//! of the ordering ablation). Hazard pointers cannot satisfy the region
//! contract and are rejected at the type level — see `smr`'s module
//! docs for why.

use std::marker::PhantomData;
use std::ptr::null_mut;
use std::sync::atomic::{AtomicIsize, AtomicPtr, AtomicUsize, Ordering};

use super::{bucket_for, census, table_capacity, ConcurrentMap, ResizeState};
use crate::atomics::{AtomicValue, BigAtomic, SeqLock};
use crate::smr::{pool, Epoch, RegionSmr};
use crate::util::backoff::snooze_lazy;
use crate::util::CachePadded;

/// The inlined first link: key, value, and a tagged next pointer.
/// Bit 0 of `next` is the occupied flag, bit 1 the resize FORWARDED
/// seal, bit 2 the CLOSING mark — `0x0` = empty bucket, `0x1` = single
/// inline entry (null next), `ptr|1` = inline entry with a chain,
/// `ptr|1|2` = FROZEN (content intact, migration copy in progress),
/// `ptr|1|2|4` = CLOSING (copy complete; the publisher is waiting out
/// straggling copiers — see [`census`](super::census)), `0x2` = DONE
/// (contents live in the next table). "Null and empty have distinct
/// meanings" (§4), and so do the seal states.
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Debug, Default)]
pub struct Link<K: AtomicValue, V: AtomicValue> {
    pub key: K,
    pub value: V,
    pub next: u64,
}

// SAFETY: repr(C) of AtomicValue fields and a u64 — all 8-byte aligned,
// sizes multiples of 8, no padding, bitwise PartialEq.
unsafe impl<K: AtomicValue, V: AtomicValue> AtomicValue for Link<K, V> {}

/// The classic single-word instantiation (§5.2's 8-byte keys/values).
pub type LinkVal = Link<u64, u64>;

impl Link<u64, u64> {
    pub const EMPTY: LinkVal = LinkVal {
        key: 0,
        value: 0,
        next: 0,
    };
}

const OCCUPIED: u64 = 1;
const FORWARDED: u64 = 2;
/// Copier window closed: set on a FROZEN image once a completed copy
/// starts draining rival copiers before the DONE transition. Chain
/// nodes are 8-byte aligned, so bit 2 of the pointer is free.
const CLOSING: u64 = 4;
const TAG_MASK: u64 = OCCUPIED | FORWARDED | CLOSING;

/// Source buckets migrated per helper claim (one stripe).
const MIGRATION_STRIPE: usize = 64;

/// Snoozes an update grants a FROZEN bucket's copier before copying the
/// bucket out itself (the copier may be preempted — or dead).
const FROZEN_PATIENCE: u32 = 16;

/// Buckets covered by one occupancy counter (the growth estimator's
/// grain — matches the migration stripe).
const OCCUPANCY_STRIPE: usize = 64;

/// Grow when a stripe's live-entry estimate exceeds this multiple of
/// its bucket count (estimated load factor threshold — the paper's
/// design point is load factor one; beyond ~2 the chains dominate).
const GROW_LOAD_FACTOR: usize = 2;

impl<K: AtomicValue, V: AtomicValue> Link<K, V> {
    /// An unoccupied bucket value.
    #[inline]
    pub fn empty() -> Self {
        Self::default()
    }

    #[inline]
    fn occupied(&self) -> bool {
        self.next & OCCUPIED == OCCUPIED
    }

    /// Any seal tag set (FROZEN, CLOSING, or DONE).
    #[inline]
    fn forwarded(&self) -> bool {
        self.next & FORWARDED == FORWARDED
    }

    /// Sealed with content, copier window open: helpers may still join
    /// the copy (after the census announce/validate handshake).
    #[inline]
    fn frozen(&self) -> bool {
        self.next & TAG_MASK == OCCUPIED | FORWARDED
    }

    /// Sealed with content, copier window closed: the frozen image is
    /// fully copied and a publisher is draining rival copiers before
    /// the DONE transition. No new copier may join.
    #[inline]
    fn closing(&self) -> bool {
        self.next & TAG_MASK == OCCUPIED | FORWARDED | CLOSING
    }

    /// This FROZEN image with the CLOSING mark added.
    #[inline]
    fn closing_image(mut self) -> Self {
        debug_assert!(self.frozen(), "closing an unsealed bucket");
        self.next |= CLOSING;
        self
    }

    /// Sealed empty: contents (if any) live in the next generation.
    #[inline]
    fn done(&self) -> bool {
        self.next & TAG_MASK == FORWARDED
    }

    /// This bucket's image with the FORWARDED seal added.
    #[inline]
    fn sealed(mut self) -> Self {
        self.next |= FORWARDED;
        self
    }

    /// The empty-forwarded sentinel a fully-migrated bucket holds.
    #[inline]
    fn done_link() -> Self {
        Link {
            key: K::default(),
            value: V::default(),
            next: FORWARDED,
        }
    }

    #[inline]
    fn next_ptr(&self) -> *mut ChainNode<K, V> {
        (self.next & !TAG_MASK) as *mut ChainNode<K, V>
    }

    #[inline]
    fn with_chain(key: K, value: V, chain: *mut ChainNode<K, V>) -> Self {
        Link {
            key,
            value,
            next: (chain as u64) | OCCUPIED,
        }
    }
}

/// Immutable-after-publish chain link.
struct ChainNode<K, V> {
    key: K,
    value: V,
    next: *mut ChainNode<K, V>,
}

/// One generation of the bucket array. Resizes allocate a fresh, larger
/// `Table`, migrate into it, and epoch-retire the drained source.
struct Table<A, K, V>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
{
    buckets: Box<[CachePadded<A>]>,
    /// Per-stripe live-entry estimates (insert +1 / remove −1) feeding
    /// the growth trigger. Signed: the +1 and −1 of a racing
    /// insert/remove pair may land in either order.
    stripes: Box<[CachePadded<AtomicIsize>]>,
    /// Buckets sealed DONE; reaching `len()` completes the migration.
    migrated: AtomicUsize,
}

impl<A, K, V> Table<A, K, V>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
{
    fn new(cap: usize) -> Self {
        let nstripes = cap.div_ceil(OCCUPANCY_STRIPE).max(1);
        Self {
            buckets: (0..cap)
                .map(|_| CachePadded::new(A::new(Link::empty())))
                .collect(),
            stripes: (0..nstripes)
                .map(|_| CachePadded::new(AtomicIsize::new(0)))
                .collect(),
            migrated: AtomicUsize::new(0),
        }
    }

    #[inline]
    fn len(&self) -> usize {
        self.buckets.len()
    }

    #[inline]
    fn bucket(&self, idx: usize) -> &A {
        &self.buckets[idx]
    }

    #[inline]
    fn stripe(&self, idx: usize) -> &AtomicIsize {
        &self.stripes[idx / OCCUPANCY_STRIPE]
    }
}

/// Free a table and every chain still linked from its buckets
/// (exclusive access — `Drop` only; DONE buckets' chains were already
/// retired at their DONE transitions).
unsafe fn drop_table<A, K, V>(ptr: *mut Table<A, K, V>)
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
{
    // SAFETY: caller guarantees exclusivity; the Box frees the arrays.
    let t = unsafe { Box::from_raw(ptr) };
    for b in t.buckets.iter() {
        let head = b.load();
        if head.occupied() {
            let mut p = head.next_ptr();
            while !p.is_null() {
                // SAFETY: exclusive in Drop; nodes come from the page pool.
                let nx = unsafe { (*p).next };
                unsafe { pool::free_node_now(p) };
                p = nx;
            }
        }
    }
}

pub struct CacheHash<A, K = u64, V = u64, S = Epoch>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    /// The live generation. Readers reach newer generations by falling
    /// through DONE seal marks; updated once a migration completes.
    root: AtomicPtr<Table<A, K, V>>,
    /// The migration descriptor (see [`ResizeState`]); a `SeqLock` big
    /// atomic so stripe claims are witness-fed CASes.
    resize: SeqLock<ResizeState>,
    /// Completed growths (each retired one drained table through `S`).
    generations: AtomicUsize,
    name: &'static str,
    _kv: PhantomData<(Link<K, V>, fn() -> S)>,
}

// SAFETY: buckets are Sync big atomics; chain nodes and drained tables
// are immutable and region-protected.
unsafe impl<A, K, V, S> Send for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
}
unsafe impl<A, K, V, S> Sync for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
}

impl<A, K, V, S> CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    /// A table with capacity for ~`n` entries at load factor one.
    /// Undershooting is no longer fatal: the table grows online once the
    /// estimated load factor crosses [`GROW_LOAD_FACTOR`].
    pub fn new(n: usize) -> Self {
        let cap = table_capacity(n);
        Self {
            root: AtomicPtr::new(Box::into_raw(Box::new(Table::new(cap)))),
            resize: SeqLock::new(ResizeState::default()),
            generations: AtomicUsize::new(0),
            name: A::name(),
            _kv: PhantomData,
        }
    }

    /// The live root table.
    ///
    /// # Safety (internal)
    /// Callers must hold the region pin: drained tables are only
    /// epoch-retired, so the reference stays valid for the pin's
    /// lifetime even across concurrent resizes.
    #[inline]
    fn root_table(&self) -> &Table<A, K, V> {
        // Ordering: Acquire — pairs with the Release root swing in
        // `finish_resize` so the promoted table's contents are visible.
        unsafe { &*self.root.load(Ordering::Acquire) }
    }

    /// The table a DONE seal mark in `t` forwards to: the in-flight
    /// migration's destination when the descriptor matches `t` *and*
    /// the root, else the (necessarily newer) root.
    fn table_after(&self, t: &Table<A, K, V>) -> &Table<A, K, V> {
        let rs = self.resize.load();
        let root = self.root.load(Ordering::Acquire);
        let tp = t as *const Table<A, K, V> as u64;
        if rs.in_flight() && rs.old == root as u64 && rs.old == tp {
            // SAFETY: the descriptor matches the live root, so `new` is
            // the live in-flight destination — pinned-protected like
            // every table.
            unsafe { &*(rs.new as *const Table<A, K, V>) }
        } else {
            // The migration that sealed `t` has completed (the root is
            // swung before the descriptor is cleared), or a later one is
            // in flight: restart from the root, which is strictly newer
            // than `t`.
            // SAFETY: root is live under the caller's pin.
            unsafe { &*root }
        }
    }

    /// Walk the (immutable) chain for `key`.
    #[inline]
    fn chain_find(mut p: *mut ChainNode<K, V>, key: &K) -> Option<V> {
        while !p.is_null() {
            // SAFETY: region-pinned by caller; nodes retired only after
            // being unlinked by a bucket CAS that happened-after our
            // head load.
            let n = unsafe { &*p };
            if n.key == *key {
                return Some(n.value);
            }
            p = n.next;
        }
        None
    }

    /// True while a migration descriptor is published.
    pub fn resize_in_flight(&self) -> bool {
        self.resize.load().in_flight()
    }

    /// Completed growths (old tables retired through `S`).
    pub fn generation(&self) -> usize {
        self.generations.load(Ordering::Acquire)
    }

    /// Drive any in-flight migration to completion — a cooperative
    /// helper for maintenance threads, drops, and tests; normal updates
    /// migrate one stripe at a time.
    ///
    /// Stall-proof: once the cursor is exhausted, this does not merely
    /// wait for stragglers — it *sweeps* every not-yet-DONE bucket
    /// itself. A claimant that died after advancing the cursor (so its
    /// stripe was claimed but never copied) would otherwise leave
    /// `migrated < len` forever with no helper able to reach the gap;
    /// `migrate_bucket` is idempotent (FROZEN takeover + DONE election),
    /// so re-covering a live straggler's stripe is harmless.
    pub fn finish_resizes(&self) {
        let _g = S::pin();
        let mut bo = None;
        loop {
            let rs = self.resize.load();
            if !rs.in_flight() {
                return;
            }
            self.help_resize();
            let root = self.root.load(Ordering::Acquire);
            if rs.old == root as u64 {
                // SAFETY: old == root — live under our pin.
                let old = unsafe { &*root };
                if rs.cursor as usize >= old.len() {
                    // Cursor exhausted but descriptor still published:
                    // re-cover any stripe whose claimant went missing.
                    // SAFETY: the descriptor matched the root when
                    // loaded; `new` is the live destination under our
                    // pin (it cannot be retired while `old` is root).
                    let new = unsafe { &*(rs.new as *const Table<A, K, V>) };
                    for idx in 0..old.len() {
                        self.migrate_bucket(old, idx, new);
                    }
                }
            }
            snooze_lazy(&mut bo);
        }
    }

    /// Account a successful insert into `t`'s stripe estimate and
    /// trigger growth when the stripe crosses the load-factor threshold.
    fn note_insert(&self, t: &Table<A, K, V>, idx: usize) {
        // Ordering: Relaxed — the stripe counters are a statistical
        // estimate; nothing synchronizes through them.
        let n = t.stripe(idx).fetch_add(1, Ordering::Relaxed) + 1;
        let span = OCCUPANCY_STRIPE.min(t.len());
        if n > (span * GROW_LOAD_FACTOR) as isize {
            self.try_begin_grow(t);
        }
    }

    fn note_remove(&self, t: &Table<A, K, V>, idx: usize) {
        // Ordering: Relaxed — as in note_insert.
        t.stripe(idx).fetch_sub(1, Ordering::Relaxed);
    }

    /// Publish a double-size destination for `t` if no migration is in
    /// flight and `t` is still the root. Requires the caller's pin.
    fn try_begin_grow(&self, t: &Table<A, K, V>) {
        if self.resize.load().in_flight() {
            return;
        }
        let tp = t as *const Table<A, K, V> as *mut Table<A, K, V>;
        // Only the root grows; a mid-migration destination grows after
        // promotion.
        if self.root.load(Ordering::Acquire) != tp {
            return;
        }
        let new: *mut Table<A, K, V> = Box::into_raw(Box::new(Table::new(t.len() * 2)));
        let desc = ResizeState {
            old: tp as u64,
            new: new as u64,
            cursor: 0,
        };
        if self.resize.compare_exchange(ResizeState::default(), desc).is_err() {
            // Lost the publish race to another grower.
            // SAFETY: never published.
            drop(unsafe { Box::from_raw(new) });
            return;
        }
        if self.root.load(Ordering::Acquire) != tp {
            // A full resize completed between our root check and the
            // publish: the descriptor is stale. Helpers ignore
            // descriptors whose `old` is not the root (and `t` cannot be
            // freed while we are pinned, so its address cannot be
            // recycled into a new root), so a successful exact retract
            // proves the fresh table is still unreferenced.
            if self.resize.compare_exchange(desc, ResizeState::default()).is_ok() {
                // SAFETY: unpublished again, never dereferenced.
                drop(unsafe { Box::from_raw(new) });
            }
            return;
        }
        // Descriptor published and still rooted: this grow is real.
        crate::counter!(ResizeGrowBegin);
        // Kick-start: migrate the first stripe ourselves.
        self.help_resize();
    }

    /// Claim and migrate one stripe of the in-flight resize (no-op when
    /// idle). Requires the caller's pin.
    fn help_resize(&self) {
        let mut rs = self.resize.load();
        if !rs.in_flight() {
            return;
        }
        let root = self.root.load(Ordering::Acquire);
        if rs.old != root as u64 {
            return; // stale descriptor (retraction pending) or finishing
        }
        // SAFETY: old == root — live under the caller's pin.
        let old = unsafe { &*root };
        let len = old.len();
        // Claim one stripe with the witnessing CAS on the cursor.
        let (start, end) = loop {
            if !rs.in_flight() || rs.old != root as u64 {
                return;
            }
            let c = rs.cursor as usize;
            if c >= len {
                return; // fully claimed; stragglers still copying
            }
            let end = (c + MIGRATION_STRIPE).min(len);
            match self.resize.compare_exchange(
                rs,
                ResizeState {
                    cursor: end as u64,
                    ..rs
                },
            ) {
                Ok(_) => {
                    crate::counter!(ResizeStripeClaim);
                    // A kill here is the dead-claimant scenario: the
                    // cursor has advanced past a stripe nobody will
                    // copy. `finish_resizes`'s sweep re-covers it.
                    crate::failpoint!(ResizeStripeClaim);
                    break (c, end);
                }
                Err(w) => rs = w,
            }
        };
        // SAFETY: the claimed descriptor matched the root — `new` is the
        // live destination.
        let new = unsafe { &*(rs.new as *const Table<A, K, V>) };
        for idx in start..end {
            self.migrate_bucket(old, idx, new);
        }
    }

    /// Seal-and-copy one source bucket into `new`. The seal-CAS winner
    /// is the *preferred* copier (updates landing on the FROZEN window
    /// wait briefly; finds read the frozen content in place) — but not
    /// the only one allowed: a FROZEN bucket whose copier stalled or
    /// died is copied again by any helper. The copy is idempotent
    /// ([`copy_entry`](Self::copy_entry) is CAS-if-absent over the
    /// immutable frozen image), the census handshake keeps every copy
    /// write pre-DONE, and the CLOSING→DONE CAS elects exactly one
    /// winner, which alone retires the chain and accounts the bucket —
    /// so a dead copier delays this bucket, never wedges it.
    fn migrate_bucket(&self, old: &Table<A, K, V>, idx: usize, new: &Table<A, K, V>) {
        let bucket = old.bucket(idx);
        let mut head = bucket.load();
        let mut bo = None;
        loop {
            if head.done() {
                // Already migrated and accounted (re-entry via
                // finish_resizes or the sweep).
                return;
            }
            if head.frozen() {
                // Takeover: the sealing copier may be stalled or dead.
                if self.copy_frozen(bucket, head, new) {
                    break; // our DONE transition: account below
                }
                return; // a rival's DONE transition accounted already
            }
            if head.closing() {
                // Copy complete; a publisher died (or is racing us)
                // between CLOSING and DONE. Drain stragglers and race
                // the transition ourselves.
                if self.publish_done(bucket, head) {
                    break;
                }
                return;
            }
            if !head.occupied() {
                // Empty source: seal straight to DONE.
                match bucket.compare_exchange(head, Link::done_link()) {
                    Ok(_) => break,
                    Err(w) => {
                        head = w;
                        snooze_lazy(&mut bo);
                    }
                }
                continue;
            }
            // Freeze the content: one-way — updates now wait, finds
            // still read the (authoritative, immutable) frozen image.
            match bucket.compare_exchange(head, head.sealed()) {
                Ok(_) => {
                    // A kill here leaves the bucket FROZEN with no
                    // copier — the takeover arm above must recover it.
                    crate::failpoint!(ResizeSealFrozen);
                    if self.copy_frozen(bucket, head.sealed(), new) {
                        break;
                    }
                    return; // a takeover helper beat us to DONE
                }
                Err(w) => {
                    head = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
        // Exactly one DONE transition per bucket reports it migrated.
        crate::counter!(ResizeBucketMigrate);
        // Ordering: AcqRel — the finisher's promotion happens-after
        // every copier's DONE publication.
        if old.migrated.fetch_add(1, Ordering::AcqRel) + 1 == old.len() {
            self.finish_resize(old);
        }
    }

    /// An update ran out of patience with a FROZEN bucket: locate the
    /// in-flight descriptor and help copy that one bucket out
    /// (idempotent takeover via [`migrate_bucket`](Self::migrate_bucket)).
    /// No-op when the descriptor moved on — the bucket's DONE transition
    /// is then already imminent or published.
    fn help_frozen_bucket(&self, t: &Table<A, K, V>, idx: usize) {
        let rs = self.resize.load();
        let tp = t as *const Table<A, K, V> as u64;
        if !rs.in_flight() || rs.old != tp || self.root.load(Ordering::Acquire) as u64 != tp {
            return;
        }
        crate::counter!(ResizeTakeover);
        // SAFETY: the descriptor matches the live root — `new` is the
        // live destination under the caller's pin.
        let new = unsafe { &*(rs.new as *const Table<A, K, V>) };
        self.migrate_bucket(t, idx, new);
    }

    /// Copy a FROZEN bucket's (immutable) image into the destination and
    /// race it through CLOSING to DONE. Returns whether *we* won the
    /// DONE transition — the winner alone retires the drained chain and
    /// must account the bucket.
    ///
    /// Safe to run concurrently with the sealing copier or any number
    /// of takeover helpers: `copy_entry` is CAS-if-absent over the same
    /// immutable image, and the [`census`](super::census) handshake
    /// guarantees no copier's destination write can land after DONE —
    /// we announce, re-validate the bucket is still exactly FROZEN
    /// (standing down if the window closed), copy, and clear the
    /// announcement before anyone may publish DONE.
    fn copy_frozen(&self, bucket: &A, frozen: Link<K, V>, new: &Table<A, K, V>) -> bool {
        debug_assert!(frozen.frozen(), "copy_frozen on an unsealed bucket");
        let addr = bucket as *const A as usize;
        {
            let _census = census::announce(addr);
            // Re-validate post-announce (the Dekker edge — see the
            // census module docs): if the bucket left FROZEN after our
            // announcement, the publisher's scan may have missed us, so
            // we must not write. The image is immutable, so any change
            // means CLOSING or DONE.
            if bucket.load() != frozen {
                // `_census` clears on this early exit path too.
            } else {
                self.copy_entry(new, frozen.key, frozen.value);
                // A kill here unwinds the census guard — the publisher
                // stops waiting for us and the copy is re-run by a
                // rival (idempotently).
                crate::failpoint!(ResizeCopyEntry);
                let mut p = frozen.next_ptr();
                while !p.is_null() {
                    // SAFETY: chain reachable from the frozen head
                    // (DONE not published, nothing retired yet);
                    // region-pinned.
                    let n = unsafe { &*p };
                    self.copy_entry(new, n.key, n.value);
                    crate::failpoint!(ResizeCopyEntry);
                    p = n.next;
                }
            }
            // Guard dropped here: our destination writes are complete
            // and visible before any publisher's scan can miss us.
        }
        // Close the copier window. One CAS winner; losers fall through
        // to the publish race on the same (deterministic) image.
        let closing = frozen.closing_image();
        let _ = bucket.compare_exchange(frozen, closing);
        self.publish_done(bucket, closing)
    }

    /// Drain straggling copiers off a CLOSING bucket, then race its
    /// CLOSING→DONE transition. Returns whether *we* won — the winner
    /// alone retires the drained chain.
    fn publish_done(&self, bucket: &A, closing: Link<K, V>) -> bool {
        debug_assert!(closing.closing(), "publish_done on a non-CLOSING image");
        let addr = bucket as *const A as usize;
        // Wait until no rival copier still announces this bucket: a
        // live one finishes its (chain-length-bounded) copy and clears;
        // a killed one's guard cleared on unwind. This wait is the
        // fence that keeps every copy write pre-DONE.
        let mut bo = None;
        while census::rivals(addr) {
            snooze_lazy(&mut bo);
        }
        // Publish DONE — the linearization point after which this
        // bucket's keys live in the destination. A kill *before* the
        // CAS re-opens the publish window (any helper re-runs this
        // phase); after a successful CAS the accounting in
        // `migrate_bucket` is fault-free by construction (no failpoints
        // between the transition and the migrated increment).
        crate::failpoint!(ResizePublishDone);
        if bucket.compare_exchange(closing, Link::done_link()).is_err() {
            return false; // a rival published DONE (the image is immutable)
        }
        // Retire the drained chain through the region scheme — winner
        // only, exactly once per bucket, as ONE page batch (one retire
        // entry and one eventual orphan-lock acquisition per chain).
        let mut batch = pool::PageBatch::new();
        let mut p = closing.next_ptr();
        while !p.is_null() {
            // SAFETY: unlinked by the DONE transition; lagging readers
            // of the frozen image are pinned, which keeps the whole
            // batch unrecycled until they unpin.
            let nx = unsafe { (*p).next };
            unsafe { batch.push(p) };
            p = nx;
        }
        // SAFETY: every pushed node is unlinked and unique.
        unsafe { S::retire_page(batch) };
        true
    }

    /// Insert-if-absent into the destination table (no growth trigger:
    /// the destination cannot resize while this migration holds the
    /// descriptor; its stripe counters still accumulate for the next
    /// cycle).
    fn copy_entry(&self, new: &Table<A, K, V>, key: K, value: V) {
        let idx = bucket_for(&key, new.len());
        let bucket = new.bucket(idx);
        let mut head = bucket.load();
        let mut bo = None;
        loop {
            debug_assert!(!head.forwarded(), "destination sealed mid-migration");
            if !head.occupied() {
                match bucket.compare_exchange(head, Link::with_chain(key, value, null_mut())) {
                    Ok(_) => {
                        // Ordering: Relaxed — estimate, as in note_insert.
                        new.stripe(idx).fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    Err(w) => {
                        head = w;
                        snooze_lazy(&mut bo);
                        continue;
                    }
                }
            }
            if head.key == key || Self::chain_find(head.next_ptr(), &key).is_some() {
                // Already present: a user insert of this key cannot land
                // here pre-DONE, so this is idempotence insurance only.
                return;
            }
            let spill = pool::alloc_node(ChainNode {
                key: head.key,
                value: head.value,
                next: head.next_ptr(),
            });
            match bucket.compare_exchange(head, Link::with_chain(key, value, spill)) {
                Ok(_) => {
                    // Ordering: Relaxed — estimate.
                    new.stripe(idx).fetch_add(1, Ordering::Relaxed);
                    return;
                }
                Err(w) => {
                    // SAFETY: never published.
                    unsafe { pool::free_node_now(spill) };
                    head = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    /// Run by the unique copier whose DONE transition drained the last
    /// bucket: promote the destination, clear the descriptor, retire the
    /// source.
    fn finish_resize(&self, old: &Table<A, K, V>) {
        let rs = self.resize.load();
        let op = old as *const Table<A, K, V> as *mut Table<A, K, V>;
        debug_assert!(rs.in_flight() && rs.old == op as u64, "finisher raced the descriptor");
        let new = rs.new as *mut Table<A, K, V>;
        // Ordering: AcqRel CAS — the Release half publishes the fully
        // populated destination to readers' Acquire root loads.
        let swung = self
            .root
            .compare_exchange(op, new, Ordering::AcqRel, Ordering::Acquire)
            .is_ok();
        debug_assert!(swung, "root moved before the finisher");
        // Clear the descriptor only after the root swing so
        // `table_after`'s descriptor-matches-root rule stays sound.
        let mut cur = rs;
        while cur.in_flight() && cur.old == op as u64 {
            match self.resize.compare_exchange(cur, ResizeState::default()) {
                Ok(_) => break,
                Err(w) => cur = w,
            }
        }
        // Ordering: AcqRel — generation reads observe a promoted root.
        self.generations.fetch_add(1, Ordering::AcqRel);
        crate::counter!(ResizeFinish);
        // Retire the drained generation — bucket array and all (every
        // bucket holds a DONE seal; chains were retired at their DONE
        // transitions). Pinned readers mid-fall-through keep it alive:
        // the region guarantee of `S`.
        // SAFETY: unlinked from both the root and the descriptor; unique.
        unsafe { S::retire_box(op) };
    }
}

impl<A, K, V, S> ConcurrentMap<K, V> for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    fn find(&self, key: K) -> Option<V> {
        let _g = S::pin();
        let mut t = self.root_table();
        loop {
            let head = t.bucket(bucket_for(&key, t.len())).load();
            if head.done() {
                // Fully migrated: fall through old → new. No lock, no
                // helping, no waiting — the find path stays lock-free.
                t = self.table_after(t);
                continue;
            }
            if !head.occupied() {
                return None;
            }
            if head.key == key {
                return Some(head.value); // the inlined fast path (frozen included)
            }
            return Self::chain_find(head.next_ptr(), &key);
        }
    }

    fn insert(&self, key: K, value: V) -> bool {
        let _g = S::pin();
        // Updates pay the incremental-migration toll: one stripe.
        self.help_resize();
        let mut t = self.root_table();
        let mut idx = bucket_for(&key, t.len());
        let mut bucket = t.bucket(idx);
        let mut head = bucket.load();
        // Bounded patience with a FROZEN bucket before helping copy it.
        let mut frozen_waits = 0u32;
        // The chain pointer we last walked and proved free of `key`.
        // Chain nodes are immutable after publish and we hold the region
        // pin for the whole operation, so no node reachable from a head
        // we read can be freed (or its address reused) before we return
        // — pointer equality therefore implies the entire chain is
        // unchanged, and a witness-fed retry whose chain pointer matches
        // skips the second walk (the duplicate check cost under
        // contention).
        let mut searched: Option<*mut ChainNode<K, V>> = None;
        // Lazy: an uncontended insert pays no backoff/TLS cost.
        let mut bo = None;
        loop {
            if head.forwarded() {
                if head.frozen() || head.closing() {
                    // The stripe owner is copying this bucket out; the
                    // window is bounded by the chain length — unless the
                    // copier died in it. Wait a bounded number of beats,
                    // then help: copy the frozen image ourselves and
                    // race its DONE transition (idempotent takeover).
                    crate::counter!(ResizeFrozenWait);
                    frozen_waits += 1;
                    if frozen_waits > FROZEN_PATIENCE {
                        frozen_waits = 0;
                        self.help_frozen_bucket(t, idx);
                    } else {
                        snooze_lazy(&mut bo);
                    }
                    head = bucket.load();
                    continue;
                }
                // DONE: this bucket's keys live in a newer generation.
                t = self.table_after(t);
                idx = bucket_for(&key, t.len());
                bucket = t.bucket(idx);
                head = bucket.load();
                searched = None;
                continue;
            }
            if !head.occupied() {
                // Empty bucket: install inline. On failure the witness
                // is the new head — no re-load.
                match bucket.compare_exchange(head, Link::with_chain(key, value, null_mut())) {
                    Ok(_) => {
                        self.note_insert(t, idx);
                        return true;
                    }
                    Err(w) => {
                        head = w;
                        snooze_lazy(&mut bo);
                        continue;
                    }
                }
            }
            if head.key == key {
                return false;
            }
            let chain = head.next_ptr();
            if searched != Some(chain) {
                if Self::chain_find(chain, &key).is_some() {
                    return false;
                }
                searched = Some(chain);
            }
            // Push-front: the new pair goes inline; the old inline pair
            // moves out to a pooled link pointing at the existing chain.
            let spill = pool::alloc_node(ChainNode {
                key: head.key,
                value: head.value,
                next: chain,
            });
            match bucket.compare_exchange(head, Link::with_chain(key, value, spill)) {
                Ok(_) => {
                    self.note_insert(t, idx);
                    return true;
                }
                Err(w) => {
                    // SAFETY: never published.
                    unsafe { pool::free_node_now(spill) };
                    head = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn remove(&self, key: K) -> bool {
        let _g = S::pin();
        // Updates pay the incremental-migration toll: one stripe.
        self.help_resize();
        let mut t = self.root_table();
        let mut idx = bucket_for(&key, t.len());
        let mut bucket = t.bucket(idx);
        let mut head = bucket.load();
        // Lazy: an uncontended remove pays no backoff/TLS cost.
        let mut bo = None;
        // Bounded patience with a FROZEN bucket before helping copy it.
        let mut frozen_waits = 0u32;
        loop {
            if head.forwarded() {
                if head.frozen() || head.closing() {
                    crate::counter!(ResizeFrozenWait);
                    frozen_waits += 1;
                    if frozen_waits > FROZEN_PATIENCE {
                        frozen_waits = 0;
                        self.help_frozen_bucket(t, idx);
                    } else {
                        snooze_lazy(&mut bo);
                    }
                    head = bucket.load();
                    continue;
                }
                t = self.table_after(t);
                idx = bucket_for(&key, t.len());
                bucket = t.bucket(idx);
                head = bucket.load();
                continue;
            }
            if !head.occupied() {
                return false;
            }
            if head.key == key {
                let p = head.next_ptr();
                if p.is_null() {
                    // Single inline entry -> empty.
                    match bucket.compare_exchange(head, Link::empty()) {
                        Ok(_) => {
                            self.note_remove(t, idx);
                            return true;
                        }
                        Err(w) => {
                            head = w;
                            snooze_lazy(&mut bo);
                            continue;
                        }
                    }
                }
                // Promote the first chain node inline.
                // SAFETY: region-pinned, reachable.
                let n = unsafe { &*p };
                let promoted = Link::with_chain(n.key, n.value, n.next);
                match bucket.compare_exchange(head, promoted) {
                    Ok(_) => {
                        // SAFETY: p unlinked by the successful CAS.
                        unsafe { pool::retire_node::<S, _>(p) };
                        self.note_remove(t, idx);
                        return true;
                    }
                    Err(w) => {
                        head = w;
                        snooze_lazy(&mut bo);
                        continue;
                    }
                }
            }
            // Delete inside the chain: path-copy the prefix (§4).
            let mut prefix: Vec<(K, V)> = Vec::new();
            let mut p = head.next_ptr();
            let mut found = false;
            let mut suffix: *mut ChainNode<K, V> = null_mut();
            while !p.is_null() {
                // SAFETY: region-pinned traversal.
                let n = unsafe { &*p };
                if n.key == key {
                    found = true;
                    suffix = n.next;
                    break;
                }
                prefix.push((n.key, n.value));
                p = n.next;
            }
            if !found {
                return false;
            }
            let victim = p;
            // Rebuild the prefix copies back-to-front onto the suffix.
            let mut new_chain = suffix;
            for &(k, v) in prefix.iter().rev() {
                new_chain = pool::alloc_node(ChainNode {
                    key: k,
                    value: v,
                    next: new_chain,
                });
            }
            let new_head = Link::with_chain(head.key, head.value, new_chain);
            match bucket.compare_exchange(head, new_head) {
                Ok(_) => {
                    // Retire the victim and the replaced original prefix.
                    // SAFETY: all unlinked by the successful CAS;
                    // pool-retired so slots recycle after the region
                    // grace period.
                    unsafe {
                        pool::retire_node::<S, _>(victim);
                        let mut q = head.next_ptr();
                        while q != victim {
                            let nx = (*q).next;
                            pool::retire_node::<S, _>(q);
                            q = nx;
                        }
                    }
                    self.note_remove(t, idx);
                    return true;
                }
                Err(w) => {
                    // CAS failed: free the unpublished copies, continue
                    // from the witnessed head.
                    let mut q = new_chain;
                    while q != suffix {
                        // SAFETY: never published.
                        let nx = unsafe { (*q).next };
                        unsafe { pool::free_node_now(q) };
                        q = nx;
                    }
                    head = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn map_name(&self) -> &'static str {
        self.name
    }

    fn capacity(&self) -> usize {
        let _g = S::pin();
        self.root_table().len()
    }

    fn occupancy(&self) -> usize {
        let _g = S::pin();
        self.root_table()
            .stripes
            .iter()
            // Ordering: Relaxed — estimate.
            .map(|s| s.load(Ordering::Relaxed))
            .sum::<isize>()
            .max(0) as usize
    }
}

impl<A, K, V, S> Drop for CacheHash<A, K, V, S>
where
    K: AtomicValue,
    V: AtomicValue,
    A: BigAtomic<Link<K, V>>,
    S: RegionSmr,
{
    fn drop(&mut self) {
        let root = *self.root.get_mut();
        let rs = self.resize.load();
        // Exclusive (&mut self): free the live table and, when a
        // migration was abandoned mid-flight, its half-built destination
        // (migration copies are fresh allocations, so the two frees are
        // disjoint; chains behind DONE seals were already retired).
        unsafe {
            if rs.in_flight() {
                debug_assert_eq!(rs.old, root as u64, "descriptor of a foreign root at drop");
                drop_table(rs.new as *mut Table<A, K, V>);
            }
            drop_table(root);
        }
        S::flush_thread_bag();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::{CachedMemEff, SeqLock, Words};
    use std::sync::Arc;

    fn basic<A: BigAtomic<LinkVal>>() {
        let t: CacheHash<A> = CacheHash::new(64);
        assert_eq!(t.find(1), None);
        assert!(t.insert(1, 10));
        assert!(!t.insert(1, 11), "duplicate insert must fail");
        assert_eq!(t.find(1), Some(10));
        assert!(t.remove(1));
        assert!(!t.remove(1));
        assert_eq!(t.find(1), None);
    }

    #[test]
    fn test_basic_seqlock() {
        basic::<SeqLock<LinkVal>>();
    }

    #[test]
    fn test_basic_memeff() {
        basic::<CachedMemEff<LinkVal>>();
    }

    #[test]
    fn test_explicit_epoch_policy_instantiations() {
        // The table is generic over the epoch ordering policy: the
        // fenced default and the blanket-SeqCst audit instantiation must
        // behave identically (the smr ablation compares them).
        use crate::smr::Epoch;
        use crate::util::ordering::{Fenced, SeqCstEverywhere};
        fn run<S: crate::smr::RegionSmr>() {
            let t: CacheHash<SeqLock<LinkVal>, u64, u64, S> = CacheHash::new(8);
            for k in 0..64u64 {
                assert!(t.insert(k, k + 1));
            }
            for k in (0..64u64).step_by(2) {
                assert!(t.remove(k));
            }
            for k in 0..64u64 {
                let want = if k % 2 == 0 { None } else { Some(k + 1) };
                assert_eq!(t.find(k), want);
            }
        }
        run::<Epoch<Fenced>>();
        run::<Epoch<SeqCstEverywhere>>();
    }

    #[test]
    fn test_generic_multiword_keys_and_values() {
        // The §5.3 arbitrary-length instantiation: 4-word keys, 4-word
        // values, including forced collisions in a tiny table.
        type K = Words<4>;
        type V = Words<4>;
        let t: CacheHash<CachedMemEff<Link<K, V>>, K, V> = CacheHash::new(4);
        for i in 0..200u64 {
            assert!(t.insert(Words([i, i ^ 7, 0, i]), Words([i; 4])));
        }
        for i in 0..200u64 {
            assert_eq!(t.find(Words([i, i ^ 7, 0, i])), Some(Words([i; 4])));
        }
        assert_eq!(t.find(Words([1, 1, 1, 1])), None);
        for i in (0..200u64).step_by(3) {
            assert!(t.remove(Words([i, i ^ 7, 0, i])));
        }
        for i in 0..200u64 {
            let want = if i % 3 == 0 { None } else { Some(Words([i; 4])) };
            assert_eq!(t.find(Words([i, i ^ 7, 0, i])), want);
        }
    }

    #[test]
    fn test_mixed_width_key_value() {
        // Asymmetric instantiation: wide key, single-word value.
        type K = Words<2>;
        let t: CacheHash<SeqLock<Link<K, u64>>, K, u64> = CacheHash::new(16);
        assert!(t.insert(Words([7, 8]), 99));
        assert_eq!(t.find(Words([7, 8])), Some(99));
        assert_eq!(t.find(Words([8, 7])), None);
        assert!(t.remove(Words([7, 8])));
    }

    #[test]
    fn test_chains_beyond_one_bucket() {
        // Tiny table forces chains (and, since the resize PR, growth);
        // all pairs must survive both.
        let t: CacheHash<SeqLock<LinkVal>> = CacheHash::new(2);
        for k in 0..100u64 {
            assert!(t.insert(k, k * 7));
        }
        for k in 0..100u64 {
            assert_eq!(t.find(k), Some(k * 7), "key {k}");
        }
        // Delete interior/head/tail mixes.
        for k in (0..100u64).step_by(3) {
            assert!(t.remove(k));
        }
        for k in 0..100u64 {
            let want = if k % 3 == 0 { None } else { Some(k * 7) };
            assert_eq!(t.find(k), want, "key {k}");
        }
    }

    #[test]
    fn test_grow_from_tiny_capacity_single_thread() {
        // Deterministic growth: a capacity-2 table absorbing 10k inserts
        // must double repeatedly, keep every pair, and end with the
        // descriptor idle (single-threaded helpers finish inline).
        let t: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(2);
        assert_eq!(t.capacity(), 2);
        for k in 0..10_000u64 {
            assert!(t.insert(k, k ^ 0xBEEF));
        }
        t.finish_resizes();
        assert!(!t.resize_in_flight());
        assert!(t.capacity() >= 2048, "capacity stuck at {}", t.capacity());
        assert!(t.generation() >= 10, "only {} doublings", t.generation());
        let occ = t.occupancy();
        assert!(
            (9_000..=11_000).contains(&occ),
            "occupancy estimate {occ} far from 10000"
        );
        // No lost keys, no duplicates: each key removes exactly once.
        for k in 0..10_000u64 {
            assert_eq!(t.find(k), Some(k ^ 0xBEEF), "key {k}");
            assert!(t.remove(k), "lost key {k}");
            assert!(!t.remove(k), "duplicated key {k}");
        }
    }

    #[test]
    fn test_concurrent_disjoint_keys() {
        let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(1024));
        let threads = 4;
        let per = 2_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|tix| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let base = tix as u64 * 1_000_000;
                    for i in 0..per {
                        assert!(t.insert(base + i, i));
                    }
                    for i in 0..per {
                        assert_eq!(t.find(base + i), Some(i));
                    }
                    for i in (0..per).step_by(2) {
                        assert!(t.remove(base + i));
                    }
                    for i in 0..per {
                        let want = if i % 2 == 0 { None } else { Some(i) };
                        assert_eq!(t.find(base + i), want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn test_concurrent_duplicate_inserts_exactly_one_winner() {
        // Both threads race to insert the same keys into a 2-bucket
        // table (long chains force the duplicate check through the
        // witness-fed retry with the searched-chain skip, and growth
        // races the inserts): every key must be inserted exactly once.
        let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(2));
        let wins = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let t = Arc::clone(&t);
                let wins = Arc::clone(&wins);
                std::thread::spawn(move || {
                    for k in 0..500u64 {
                        if t.insert(k, k + 1) {
                            wins.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(wins.load(std::sync::atomic::Ordering::SeqCst), 500);
        for k in 0..500u64 {
            assert_eq!(t.find(k), Some(k + 1), "key {k}");
        }
    }

    #[test]
    fn test_concurrent_same_key_contention() {
        // Insert/remove storms on one key: at the end, state must be
        // consistent with the net count of successful ops.
        let t: Arc<CacheHash<CachedMemEff<LinkVal>>> = Arc::new(CacheHash::new(8));
        let inserts = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let removes = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let handles: Vec<_> = (0..4)
            .map(|tix| {
                let t = Arc::clone(&t);
                let inserts = Arc::clone(&inserts);
                let removes = Arc::clone(&removes);
                std::thread::spawn(move || {
                    for i in 0..4_000u64 {
                        if (i + tix) % 2 == 0 {
                            if t.insert(42, i) {
                                inserts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        } else if t.remove(42) {
                            removes.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let ins = inserts.load(std::sync::atomic::Ordering::SeqCst);
        let rem = removes.load(std::sync::atomic::Ordering::SeqCst);
        let present = t.find(42).is_some() as u64;
        assert_eq!(ins, rem + present, "ins={ins} rem={rem} present={present}");
    }
}
