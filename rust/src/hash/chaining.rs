//! Chaining — the paper's no-inlining baseline (§4 first paragraph,
//! "Chaining" series in Fig 3): identical algorithm to CacheHash but the
//! bucket is a plain atomic *word* (a tagged pointer to the first link),
//! so every non-empty find pays at least one extra dependent cache miss.
//! Generic over the same key/value types as [`CacheHash`](super::CacheHash),
//! and over the same region-grained reclamation parameter (epoch-based;
//! see `smr` for why hazard pointers are rejected at the type level).
//!
//! Resizes online exactly like `CacheHash` — both run the shared
//! [`resize`](super::resize) engine (descriptor lifecycle, stripe
//! claims, seals, census-fenced takeover, hysteresis triggers for grow
//! *and* shrink, drained-table retirement). This file contributes only
//! the tagged-word bucket encoding — FROZEN (`ptr|1`, content intact) →
//! CLOSING (`ptr|1|2`, copy complete, rival copiers draining) → DONE
//! (`1`) — plus `copy_image` (insert-if-absent chain copy) and
//! page-batched chain retirement. Finds stay lock-free, falling
//! through DONE marks.
//!
//! The bucket protocol is on the memory-ordering diet (PR 3/4 house
//! style): every access runs at the weakest sound ordering under the
//! [`OrderingPolicy`](crate::util::ordering::OrderingPolicy) constants
//! of `DefaultPolicy` (so `--features seqcst_audit` restores blanket
//! `SeqCst`), each site carrying an `// Ordering:` comment naming its
//! happens-before edge. Inserts also reuse the failed-CAS witness: the
//! chain suffix a previous walk proved duplicate-free is skipped on
//! retry (nodes are immutable and region-pinned, so pointer equality
//! identifies the proven suffix).

use std::marker::PhantomData;
use std::sync::atomic::{AtomicIsize, AtomicPtr, AtomicUsize, Ordering};

use super::resize::{self, Maintain, ResizeTable, FROZEN_PATIENCE, OCCUPANCY_STRIPE};
use super::{bucket_for, table_capacity, ConcurrentMap, ResizeState};
use crate::atomics::{AtomicValue, SeqLock};
use crate::smr::{pool, Epoch, RegionSmr};
use crate::util::backoff::snooze_lazy;
use crate::util::ordering::{DefaultPolicy as P, OrderingPolicy};
use crate::util::CachePadded;

struct Node<K, V> {
    key: K,
    value: V,
    next: *mut Node<K, V>,
}

/// Bucket tag bits (nodes are ≥ 8-byte aligned, so bits 0–2 are free):
/// `0` = empty, `p` = chain head, `p|1` = FROZEN (copy in progress,
/// helpers may join), `p|1|2` = CLOSING (copy complete, publisher
/// draining rival copiers — see [`census`](super::census)), `1` = DONE
/// (contents live in the next generation).
const FWD: usize = 1;
/// Copier window closed (set only on a FROZEN image).
const CLOSING: usize = 2;

#[inline]
fn node_of<K, V>(raw: usize) -> *mut Node<K, V> {
    (raw & !(FWD | CLOSING)) as *mut Node<K, V>
}

/// Sealed with content, copier window open.
#[inline]
fn is_frozen(raw: usize) -> bool {
    raw & FWD != 0 && raw & CLOSING == 0 && raw != FWD
}

/// Sealed with content, copier window closed.
#[inline]
fn is_closing(raw: usize) -> bool {
    raw & CLOSING != 0
}

/// One generation of the bucket array (see `CacheHash`'s `Table`).
/// Public only because it is the [`ResizeTable::Table`] associated
/// type; its fields and methods are module-private.
pub struct CTable<K, V> {
    buckets: Box<[CachePadded<AtomicUsize>]>,
    stripes: Box<[CachePadded<std::sync::atomic::AtomicIsize>]>,
    migrated: AtomicUsize,
    _kv: PhantomData<(K, V)>,
}

impl<K: AtomicValue, V: AtomicValue> CTable<K, V> {
    fn new(cap: usize) -> Self {
        let nstripes = cap.div_ceil(OCCUPANCY_STRIPE).max(1);
        Self {
            buckets: (0..cap).map(|_| CachePadded::new(AtomicUsize::new(0))).collect(),
            stripes: (0..nstripes)
                .map(|_| CachePadded::new(std::sync::atomic::AtomicIsize::new(0)))
                .collect(),
            migrated: AtomicUsize::new(0),
            _kv: PhantomData,
        }
    }

    #[inline]
    fn len(&self) -> usize {
        self.buckets.len()
    }

    #[inline]
    fn bucket(&self, idx: usize) -> &AtomicUsize {
        &self.buckets[idx]
    }

    #[inline]
    fn stripe(&self, idx: usize) -> &std::sync::atomic::AtomicIsize {
        &self.stripes[idx / OCCUPANCY_STRIPE]
    }
}

/// Free a table and every chain still linked from its buckets
/// (exclusive access — `Drop` only).
unsafe fn drop_ctable<K: AtomicValue, V: AtomicValue>(ptr: *mut CTable<K, V>) {
    // SAFETY: caller guarantees exclusivity.
    let t = unsafe { Box::from_raw(ptr) };
    for b in t.buckets.iter() {
        let raw = b.load(Ordering::Relaxed);
        let mut p = node_of::<K, V>(raw);
        while !p.is_null() {
            // SAFETY: exclusive in Drop; nodes come from the page pool.
            let nx = unsafe { (*p).next };
            unsafe { pool::free_node_now(p) };
            p = nx;
        }
    }
}

pub struct Chaining<K: AtomicValue = u64, V: AtomicValue = u64, S: RegionSmr = Epoch> {
    /// The live generation (see `CacheHash::root`).
    root: AtomicPtr<CTable<K, V>>,
    /// The migration descriptor, published via a big atomic.
    resize: SeqLock<ResizeState>,
    /// Completed grows.
    generations: AtomicUsize,
    /// Completed shrinks.
    shrink_generations: AtomicUsize,
    /// Construction-time capacity: shrink never halves below this.
    floor: usize,
    _smr: PhantomData<fn() -> S>,
}

// SAFETY: mutations via CAS on bucket words; nodes immutable + region SMR.
unsafe impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Send for Chaining<K, V, S> {}
unsafe impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Sync for Chaining<K, V, S> {}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Chaining<K, V, S> {
    pub fn new(n: usize) -> Self {
        let cap = table_capacity(n);
        Self {
            root: AtomicPtr::new(Box::into_raw(Box::new(CTable::new(cap)))),
            resize: SeqLock::new(ResizeState::default()),
            generations: AtomicUsize::new(0),
            shrink_generations: AtomicUsize::new(0),
            floor: cap,
            _smr: PhantomData,
        }
    }

    #[inline]
    fn chain_find(mut p: *mut Node<K, V>, key: &K) -> Option<V> {
        while !p.is_null() {
            // SAFETY: region-pinned by caller.
            let n = unsafe { &*p };
            if n.key == *key {
                return Some(n.value);
            }
            p = n.next;
        }
        None
    }

    /// True while a migration descriptor is published.
    pub fn resize_in_flight(&self) -> bool {
        self.resize.load().in_flight()
    }

    /// Completed grows (old tables retired through `S`).
    pub fn generation(&self) -> usize {
        self.generations.load(Ordering::Acquire)
    }

    /// Completed shrinks (half-size migrations that returned memory).
    pub fn shrink_generation(&self) -> usize {
        self.shrink_generations.load(Ordering::Acquire)
    }

    /// Drive any in-flight migration (either direction) to completion
    /// (tests, maintenance) — see [`resize::finish_resizes`] for the
    /// stall-proofing argument.
    pub fn finish_resizes(&self) {
        let _g = S::pin();
        resize::finish_resizes(self);
    }

    /// Insert-if-absent into the destination (no growth trigger — the
    /// descriptor is held; counters accumulate for the next cycle).
    fn copy_entry(&self, new: &CTable<K, V>, key: K, value: V) {
        let idx = bucket_for(&key, new.len());
        let bucket = new.bucket(idx);
        // Ordering: ACQUIRE — head dereferenced below.
        let mut raw = bucket.load(P::ACQUIRE);
        let fresh = pool::alloc_node(Node {
            key,
            value,
            next: std::ptr::null_mut(),
        });
        let mut bo = None;
        loop {
            debug_assert_eq!(raw & FWD, 0, "destination sealed mid-migration");
            let head = node_of::<K, V>(raw);
            if Self::chain_find(head, &key).is_some() {
                // SAFETY: never published — idempotence insurance.
                unsafe { pool::free_node_now(fresh) };
                return;
            }
            // SAFETY: unpublished, exclusively ours until the CAS wins.
            unsafe { (*fresh).next = head };
            // Ordering: RELEASE on success publishes the node's contents
            // before its address; ACQUIRE on failure — the witness head
            // is walked on retry.
            match bucket.compare_exchange(raw, fresh as usize, P::RELEASE, P::ACQUIRE) {
                Ok(_) => {
                    // Ordering: RELAXED — estimate.
                    new.stripe(idx).fetch_add(1, P::RELAXED);
                    return;
                }
                Err(w) => {
                    raw = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }
}

// SAFETY: every method is called under the region pin (`S: RegionSmr`);
// buckets are plain atomic words with witnessed-failure CAS; the tag
// predicates mirror the FWD/CLOSING encoding exactly; `copy_image` is
// insert-if-absent over an immutable chain; `retire_image`/
// `retire_drained_table` go through the region scheme.
unsafe impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> ResizeTable for Chaining<K, V, S> {
    type Table = CTable<K, V>;
    type Image = usize;

    fn resize_cell(&self) -> &SeqLock<ResizeState> {
        &self.resize
    }

    fn root_cell(&self) -> &AtomicPtr<CTable<K, V>> {
        &self.root
    }

    fn grow_cell(&self) -> &AtomicUsize {
        &self.generations
    }

    fn shrink_cell(&self) -> &AtomicUsize {
        &self.shrink_generations
    }

    fn floor(&self) -> usize {
        self.floor
    }

    fn alloc_table(&self, cap: usize) -> *mut CTable<K, V> {
        Box::into_raw(Box::new(CTable::new(cap)))
    }

    unsafe fn free_unpublished_table(&self, t: *mut CTable<K, V>) {
        // SAFETY: never published (engine contract) — plain Box drop;
        // a fresh table has no chains.
        drop(unsafe { Box::from_raw(t) });
    }

    unsafe fn retire_drained_table(&self, t: *mut CTable<K, V>) {
        // SAFETY: unlinked from root and descriptor (engine contract).
        unsafe { S::retire_box(t) };
    }

    fn len_of(t: &CTable<K, V>) -> usize {
        t.len()
    }

    fn migrated_of(t: &CTable<K, V>) -> &AtomicUsize {
        &t.migrated
    }

    fn stripe_of(t: &CTable<K, V>, idx: usize) -> &AtomicIsize {
        t.stripe(idx)
    }

    fn occupancy_of(t: &CTable<K, V>) -> isize {
        // Ordering: RELAXED — estimate.
        t.stripes.iter().map(|s| s.load(P::RELAXED)).sum()
    }

    fn load_bucket(t: &CTable<K, V>, idx: usize) -> usize {
        // Ordering: ACQUIRE — the head may be dereferenced by the
        // engine's copy path.
        t.bucket(idx).load(P::ACQUIRE)
    }

    fn cas_bucket(t: &CTable<K, V>, idx: usize, cur: usize, new: usize) -> Result<(), usize> {
        // Ordering: RELEASE publishes seals/copies before the state
        // change; ACQUIRE failure — the witness may be dereferenced on
        // retry (a sound strengthening of the pre-engine RELAXED
        // failure sites).
        t.bucket(idx)
            .compare_exchange(cur, new, P::RELEASE, P::ACQUIRE)
            .map(|_| ())
    }

    fn bucket_addr(t: &CTable<K, V>, idx: usize) -> usize {
        t.bucket(idx) as *const AtomicUsize as usize
    }

    fn is_done(img: usize) -> bool {
        img == FWD
    }

    fn is_frozen(img: usize) -> bool {
        is_frozen(img)
    }

    fn is_closing(img: usize) -> bool {
        is_closing(img)
    }

    fn is_empty_img(img: usize) -> bool {
        img == 0
    }

    fn sealed(img: usize) -> usize {
        img | FWD
    }

    fn closing_of(img: usize) -> usize {
        img | CLOSING
    }

    fn done_img() -> usize {
        FWD
    }

    fn copy_image(&self, new: &CTable<K, V>, img: usize) {
        let mut p = node_of::<K, V>(img);
        while !p.is_null() {
            // SAFETY: frozen chain (DONE not published, nothing retired
            // yet), region-pinned.
            let n = unsafe { &*p };
            self.copy_entry(new, n.key, n.value);
            // A kill here unwinds the census guard — a rival re-runs
            // the copy idempotently.
            crate::failpoint!(ResizeCopyEntry);
            p = n.next;
        }
    }

    unsafe fn retire_image(&self, img: usize) {
        // Retire the drained chain through the region scheme as ONE
        // page batch (one retire entry and one eventual orphan-lock
        // acquisition per chain, however long it was).
        let mut batch = pool::PageBatch::new();
        let mut p = node_of::<K, V>(img);
        while !p.is_null() {
            // SAFETY: unlinked by the DONE transition; lagging
            // frozen-image readers are pinned, which keeps the whole
            // batch unrecycled until they unpin.
            let nx = unsafe { (*p).next };
            unsafe { batch.push(p) };
            p = nx;
        }
        // SAFETY: every pushed node is unlinked and unique.
        unsafe { S::retire_page(batch) };
    }
}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Maintain for Chaining<K, V, S> {
    fn maintain(&self) -> bool {
        {
            let _g = S::pin();
            resize::try_begin_shrink(self, resize::root_table(self));
        }
        self.finish_resizes();
        !self.resize_in_flight()
    }
}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> ConcurrentMap<K, V> for Chaining<K, V, S> {
    fn find(&self, key: K) -> Option<V> {
        let _g = S::pin();
        let mut t = resize::root_table(self);
        loop {
            // Ordering: ACQUIRE — pairs with the RELEASE install CAS so
            // node contents are visible before the walk; the pin (not
            // this load) keeps the nodes alive.
            let raw = t.bucket(bucket_for(&key, t.len())).load(P::ACQUIRE);
            if raw == FWD {
                // DONE: fall through old → new, lock-free.
                t = resize::table_after(self, t);
                continue;
            }
            // FROZEN (`p|1`) reads its content in place — the frozen
            // image is authoritative until the DONE transition.
            return Self::chain_find(node_of::<K, V>(raw), &key);
        }
    }

    fn insert(&self, key: K, value: V) -> bool {
        let _g = S::pin();
        // Updates pay the incremental-migration toll: one stripe.
        resize::help_resize(self);
        let mut t = resize::root_table(self);
        let mut idx = bucket_for(&key, t.len());
        let mut bucket = t.bucket(idx);
        // Ordering: ACQUIRE — the head is dereferenced below.
        let mut raw = bucket.load(P::ACQUIRE);
        // The chain suffix already proven free of `key`: nodes are
        // immutable after publish and region-pinned (no address reuse
        // within this op), so pointer equality identifies the proven
        // suffix and the retry walks only the new prefix.
        let mut searched: *mut Node<K, V> = std::ptr::null_mut();
        let mut have_searched = false;
        // The spare (never-published) pool node from a failed CAS is
        // reused on retry and freed on a duplicate hit.
        let mut spare: *mut Node<K, V> = std::ptr::null_mut();
        let mut bo = None;
        // Bounded patience with a FROZEN bucket before helping copy it.
        let mut frozen_waits = 0u32;
        loop {
            if raw & FWD != 0 {
                if raw != FWD {
                    // FROZEN/CLOSING: the copier's window is chain-
                    // bounded — unless the copier died in it. Wait a
                    // bounded number of beats, then help (idempotent
                    // takeover via `help_frozen_bucket`).
                    resize::note_frozen_wait(self, t);
                    frozen_waits += 1;
                    if frozen_waits > FROZEN_PATIENCE {
                        frozen_waits = 0;
                        resize::help_frozen_bucket(self, t, idx);
                    } else {
                        snooze_lazy(&mut bo);
                    }
                    raw = bucket.load(P::ACQUIRE);
                    continue;
                }
                // DONE: hop generations.
                t = resize::table_after(self, t);
                idx = bucket_for(&key, t.len());
                bucket = t.bucket(idx);
                raw = bucket.load(P::ACQUIRE);
                have_searched = false;
                continue;
            }
            let head = node_of::<K, V>(raw);
            // Duplicate check, skipping the already-proven suffix.
            let mut p = head;
            while !p.is_null() && !(have_searched && p == searched) {
                // SAFETY: region-pinned traversal of immutable nodes.
                let n = unsafe { &*p };
                if n.key == key {
                    if !spare.is_null() {
                        // SAFETY: never published.
                        unsafe { pool::free_node_now(spare) };
                    }
                    return false;
                }
                p = n.next;
            }
            searched = head;
            have_searched = true;
            let fresh = if spare.is_null() {
                pool::alloc_node(Node {
                    key,
                    value,
                    next: head,
                })
            } else {
                let f = spare;
                spare = std::ptr::null_mut();
                // SAFETY: our never-published spare — exclusive.
                unsafe { (*f).next = head };
                f
            };
            // Ordering: RELEASE on success publishes the node's contents
            // before its address; ACQUIRE on failure — the witness head
            // is walked on retry (no re-load).
            match bucket.compare_exchange(raw, fresh as usize, P::RELEASE, P::ACQUIRE) {
                Ok(_) => {
                    resize::note_insert(self, t, idx);
                    return true;
                }
                Err(w) => {
                    // The node stays unpublished; keep it for the retry.
                    spare = fresh;
                    raw = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn remove(&self, key: K) -> bool {
        let _g = S::pin();
        // Updates pay the incremental-migration toll: one stripe.
        resize::help_resize(self);
        let mut t = resize::root_table(self);
        let mut idx = bucket_for(&key, t.len());
        let mut bucket = t.bucket(idx);
        // Ordering: ACQUIRE — the head is dereferenced below.
        let mut raw = bucket.load(P::ACQUIRE);
        let mut bo = None;
        // Bounded patience with a FROZEN bucket before helping copy it.
        let mut frozen_waits = 0u32;
        loop {
            if raw & FWD != 0 {
                if raw != FWD {
                    resize::note_frozen_wait(self, t);
                    frozen_waits += 1;
                    if frozen_waits > FROZEN_PATIENCE {
                        frozen_waits = 0;
                        resize::help_frozen_bucket(self, t, idx);
                    } else {
                        snooze_lazy(&mut bo);
                    }
                    raw = bucket.load(P::ACQUIRE);
                    continue;
                }
                t = resize::table_after(self, t);
                idx = bucket_for(&key, t.len());
                bucket = t.bucket(idx);
                raw = bucket.load(P::ACQUIRE);
                continue;
            }
            let head = node_of::<K, V>(raw);
            // Find the victim, collecting the prefix to path-copy.
            let mut prefix: Vec<(K, V)> = Vec::new();
            let mut p = head;
            let mut suffix: *mut Node<K, V> = std::ptr::null_mut();
            let mut found = false;
            while !p.is_null() {
                // SAFETY: region-pinned.
                let n = unsafe { &*p };
                if n.key == key {
                    found = true;
                    suffix = n.next;
                    break;
                }
                prefix.push((n.key, n.value));
                p = n.next;
            }
            if !found {
                return false;
            }
            let victim = p;
            let mut new_head = suffix;
            for &(k, v) in prefix.iter().rev() {
                new_head = pool::alloc_node(Node {
                    key: k,
                    value: v,
                    next: new_head,
                });
            }
            // Ordering: RELEASE on success publishes the path copies;
            // ACQUIRE on failure — the witness head is walked on retry.
            match bucket.compare_exchange(raw, new_head as usize, P::RELEASE, P::ACQUIRE) {
                Ok(_) => {
                    // SAFETY: victim + original prefix unlinked by the
                    // CAS; pool-retired so slots recycle after the
                    // region grace period.
                    unsafe {
                        pool::retire_node::<S, _>(victim);
                        let mut q = head;
                        while q != victim {
                            let nx = (*q).next;
                            pool::retire_node::<S, _>(q);
                            q = nx;
                        }
                    }
                    resize::note_remove(self, t, idx);
                    return true;
                }
                Err(w) => {
                    let mut q = new_head;
                    while q != suffix {
                        // SAFETY: never published.
                        let nx = unsafe { (*q).next };
                        unsafe { pool::free_node_now(q) };
                        q = nx;
                    }
                    raw = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn map_name(&self) -> &'static str {
        "Chaining(no-inline)"
    }

    fn capacity(&self) -> usize {
        let _g = S::pin();
        resize::root_table(self).len()
    }

    fn occupancy(&self) -> usize {
        let _g = S::pin();
        <Self as ResizeTable>::occupancy_of(resize::root_table(self)).max(0) as usize
    }

    fn shrink_generation(&self) -> usize {
        Chaining::shrink_generation(self)
    }
}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Drop for Chaining<K, V, S> {
    fn drop(&mut self) {
        let root = *self.root.get_mut();
        let rs = self.resize.load();
        // Exclusive (&mut self) — see CacheHash::drop.
        unsafe {
            if rs.in_flight() {
                debug_assert_eq!(rs.old, root as u64, "descriptor of a foreign root at drop");
                drop_ctable(rs.new as *mut CTable<K, V>);
            }
            drop_ctable(root);
        }
        S::flush_thread_bag();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_basic() {
        let t: Chaining = Chaining::new(64);
        assert!(t.insert(5, 50));
        assert!(!t.insert(5, 51));
        assert_eq!(t.find(5), Some(50));
        assert!(t.remove(5));
        assert_eq!(t.find(5), None);
    }

    #[test]
    fn test_generic_multiword() {
        let t: Chaining<Words<3>, Words<2>> = Chaining::new(8);
        assert!(t.insert(Words([1, 2, 3]), Words([4, 5])));
        assert!(!t.insert(Words([1, 2, 3]), Words([0, 0])));
        assert_eq!(t.find(Words([1, 2, 3])), Some(Words([4, 5])));
        assert_eq!(t.find(Words([3, 2, 1])), None);
        assert!(t.remove(Words([1, 2, 3])));
        assert_eq!(t.find(Words([1, 2, 3])), None);
    }

    #[test]
    fn test_collisions_and_interior_delete() {
        let t: Chaining = Chaining::new(2);
        for k in 0..50u64 {
            assert!(t.insert(k, k + 100));
        }
        for k in (0..25u64).map(|i| 48 - 2 * i) {
            assert!(t.remove(k));
        }
        for k in 0..50u64 {
            let want = if k % 2 == 0 { None } else { Some(k + 100) };
            assert_eq!(t.find(k), want);
        }
    }

    #[test]
    fn test_grow_from_tiny_capacity_single_thread() {
        // Deterministic growth mirror of the CacheHash case: a
        // capacity-2 baseline table absorbing 5k inserts must double
        // repeatedly with no lost or duplicated keys.
        let t: Chaining = Chaining::new(2);
        assert_eq!(t.capacity(), 2);
        for k in 0..5_000u64 {
            assert!(t.insert(k, !k));
        }
        t.finish_resizes();
        assert!(!t.resize_in_flight());
        assert!(t.capacity() >= 1024, "capacity stuck at {}", t.capacity());
        assert!(t.generation() >= 9, "only {} doublings", t.generation());
        for k in 0..5_000u64 {
            assert_eq!(t.find(k), Some(!k), "key {k}");
            assert!(t.remove(k), "lost key {k}");
            assert!(!t.remove(k), "duplicated key {k}");
        }
    }

    #[test]
    fn test_concurrent_mixed() {
        let t: Arc<Chaining> = Arc::new(Chaining::new(256));
        let handles: Vec<_> = (0..4)
            .map(|tix| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let base = tix as u64 * 1_000_000;
                    for i in 0..2_000u64 {
                        assert!(t.insert(base + i, i));
                        if i % 2 == 0 {
                            assert!(t.remove(base + i));
                        }
                    }
                    for i in 0..2_000u64 {
                        let want = if i % 2 == 0 { None } else { Some(i) };
                        assert_eq!(t.find(base + i), want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
