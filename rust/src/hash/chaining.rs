//! Chaining — the paper's no-inlining baseline (§4 first paragraph,
//! "Chaining" series in Fig 3): identical algorithm to CacheHash but the
//! bucket is a plain atomic *word* (a tagged pointer to the first link),
//! so every non-empty find pays at least one extra dependent cache miss.
//! Generic over the same key/value types as [`CacheHash`](super::CacheHash),
//! and over the same region-grained reclamation parameter (epoch-based;
//! see `smr` for why hazard pointers are rejected at the type level).
//!
//! Grows online exactly like `CacheHash` (see its module docs): a
//! [`ResizeState`](super::ResizeState) descriptor, stripe-claimed
//! migration, FROZEN (`ptr|1`, content intact) → CLOSING (`ptr|1|2`,
//! copy complete, rival copiers draining) → DONE (`1`) bucket seals,
//! lock-free finds falling through DONE marks, census-fenced copier
//! takeover of stalled/dead copiers, and epoch-retired drained tables.
//!
//! The bucket protocol is on the memory-ordering diet (PR 3/4 house
//! style): every access runs at the weakest sound ordering under the
//! [`OrderingPolicy`](crate::util::ordering::OrderingPolicy) constants
//! of `DefaultPolicy` (so `--features seqcst_audit` restores blanket
//! `SeqCst`), each site carrying an `// Ordering:` comment naming its
//! happens-before edge. Inserts also reuse the failed-CAS witness: the
//! chain suffix a previous walk proved duplicate-free is skipped on
//! retry (nodes are immutable and region-pinned, so pointer equality
//! identifies the proven suffix).

use std::marker::PhantomData;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

use super::{bucket_for, census, table_capacity, ConcurrentMap, ResizeState};
use crate::atomics::{AtomicValue, BigAtomic, SeqLock};
use crate::smr::{pool, Epoch, RegionSmr};
use crate::util::backoff::snooze_lazy;
use crate::util::ordering::{DefaultPolicy as P, OrderingPolicy};
use crate::util::CachePadded;

struct Node<K, V> {
    key: K,
    value: V,
    next: *mut Node<K, V>,
}

/// Bucket tag bits (nodes are ≥ 8-byte aligned, so bits 0–2 are free):
/// `0` = empty, `p` = chain head, `p|1` = FROZEN (copy in progress,
/// helpers may join), `p|1|2` = CLOSING (copy complete, publisher
/// draining rival copiers — see [`census`](super::census)), `1` = DONE
/// (contents live in the next generation).
const FWD: usize = 1;
/// Copier window closed (set only on a FROZEN image).
const CLOSING: usize = 2;

#[inline]
fn node_of<K, V>(raw: usize) -> *mut Node<K, V> {
    (raw & !(FWD | CLOSING)) as *mut Node<K, V>
}

/// Sealed with content, copier window open.
#[inline]
fn is_frozen(raw: usize) -> bool {
    raw & FWD != 0 && raw & CLOSING == 0 && raw != FWD
}

/// Sealed with content, copier window closed.
#[inline]
fn is_closing(raw: usize) -> bool {
    raw & CLOSING != 0
}

/// Source buckets migrated per helper claim / occupancy-counter grain /
/// growth threshold — shared with `CacheHash` by construction.
const MIGRATION_STRIPE: usize = 64;
const OCCUPANCY_STRIPE: usize = 64;
const GROW_LOAD_FACTOR: usize = 2;

/// Snoozes an update grants a FROZEN bucket's copier before copying the
/// bucket out itself (the copier may be preempted — or dead).
const FROZEN_PATIENCE: u32 = 16;

/// One generation of the bucket array (see `CacheHash`'s `Table`).
struct CTable<K, V> {
    buckets: Box<[CachePadded<AtomicUsize>]>,
    stripes: Box<[CachePadded<std::sync::atomic::AtomicIsize>]>,
    migrated: AtomicUsize,
    _kv: PhantomData<(K, V)>,
}

impl<K: AtomicValue, V: AtomicValue> CTable<K, V> {
    fn new(cap: usize) -> Self {
        let nstripes = cap.div_ceil(OCCUPANCY_STRIPE).max(1);
        Self {
            buckets: (0..cap).map(|_| CachePadded::new(AtomicUsize::new(0))).collect(),
            stripes: (0..nstripes)
                .map(|_| CachePadded::new(std::sync::atomic::AtomicIsize::new(0)))
                .collect(),
            migrated: AtomicUsize::new(0),
            _kv: PhantomData,
        }
    }

    #[inline]
    fn len(&self) -> usize {
        self.buckets.len()
    }

    #[inline]
    fn bucket(&self, idx: usize) -> &AtomicUsize {
        &self.buckets[idx]
    }

    #[inline]
    fn stripe(&self, idx: usize) -> &std::sync::atomic::AtomicIsize {
        &self.stripes[idx / OCCUPANCY_STRIPE]
    }
}

/// Free a table and every chain still linked from its buckets
/// (exclusive access — `Drop` only).
unsafe fn drop_ctable<K: AtomicValue, V: AtomicValue>(ptr: *mut CTable<K, V>) {
    // SAFETY: caller guarantees exclusivity.
    let t = unsafe { Box::from_raw(ptr) };
    for b in t.buckets.iter() {
        let raw = b.load(Ordering::Relaxed);
        let mut p = node_of::<K, V>(raw);
        while !p.is_null() {
            // SAFETY: exclusive in Drop; nodes come from the page pool.
            let nx = unsafe { (*p).next };
            unsafe { pool::free_node_now(p) };
            p = nx;
        }
    }
}

pub struct Chaining<K: AtomicValue = u64, V: AtomicValue = u64, S: RegionSmr = Epoch> {
    /// The live generation (see `CacheHash::root`).
    root: AtomicPtr<CTable<K, V>>,
    /// The migration descriptor, published via a big atomic.
    resize: SeqLock<ResizeState>,
    /// Completed growths.
    generations: AtomicUsize,
    _smr: PhantomData<fn() -> S>,
}

// SAFETY: mutations via CAS on bucket words; nodes immutable + region SMR.
unsafe impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Send for Chaining<K, V, S> {}
unsafe impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Sync for Chaining<K, V, S> {}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Chaining<K, V, S> {
    pub fn new(n: usize) -> Self {
        let cap = table_capacity(n);
        Self {
            root: AtomicPtr::new(Box::into_raw(Box::new(CTable::new(cap)))),
            resize: SeqLock::new(ResizeState::default()),
            generations: AtomicUsize::new(0),
            _smr: PhantomData,
        }
    }

    /// The live root table (callers must hold the region pin).
    #[inline]
    fn root_table(&self) -> &CTable<K, V> {
        // Ordering: ACQUIRE — pairs with the RELEASE root swing in
        // `finish_resize` so the promoted table's contents are visible.
        unsafe { &*self.root.load(P::ACQUIRE) }
    }

    /// The table a DONE mark in `t` forwards to (see
    /// `CacheHash::table_after` for the full argument).
    fn table_after(&self, t: &CTable<K, V>) -> &CTable<K, V> {
        let rs = self.resize.load();
        // Ordering: ACQUIRE — as in root_table.
        let root = self.root.load(P::ACQUIRE);
        let tp = t as *const CTable<K, V> as u64;
        if rs.in_flight() && rs.old == root as u64 && rs.old == tp {
            // SAFETY: descriptor matches the live root — `new` is the
            // live destination, pin-protected.
            unsafe { &*(rs.new as *const CTable<K, V>) }
        } else {
            // SAFETY: root is live under the caller's pin.
            unsafe { &*root }
        }
    }

    #[inline]
    fn chain_find(mut p: *mut Node<K, V>, key: &K) -> Option<V> {
        while !p.is_null() {
            // SAFETY: region-pinned by caller.
            let n = unsafe { &*p };
            if n.key == *key {
                return Some(n.value);
            }
            p = n.next;
        }
        None
    }

    /// True while a migration descriptor is published.
    pub fn resize_in_flight(&self) -> bool {
        self.resize.load().in_flight()
    }

    /// Completed growths (old tables retired through `S`).
    pub fn generation(&self) -> usize {
        self.generations.load(Ordering::Acquire)
    }

    /// Drive any in-flight migration to completion (tests, maintenance).
    ///
    /// Stall-proof like `CacheHash::finish_resizes`: once the cursor is
    /// exhausted this *sweeps* every not-yet-DONE bucket itself, so a
    /// claimant that died after advancing the cursor cannot leave
    /// `migrated < len` forever (`migrate_bucket` is idempotent).
    pub fn finish_resizes(&self) {
        let _g = S::pin();
        let mut bo = None;
        loop {
            let rs = self.resize.load();
            if !rs.in_flight() {
                return;
            }
            self.help_resize();
            let root = self.root.load(P::ACQUIRE);
            if rs.old == root as u64 {
                // SAFETY: old == root — live under our pin.
                let old = unsafe { &*root };
                if rs.cursor as usize >= old.len() {
                    // Cursor exhausted but descriptor still published:
                    // re-cover any stripe whose claimant went missing.
                    // SAFETY: the descriptor matched the root when
                    // loaded; `new` is the live destination under our
                    // pin (it cannot be retired while `old` is root).
                    let new = unsafe { &*(rs.new as *const CTable<K, V>) };
                    for idx in 0..old.len() {
                        self.migrate_bucket(old, idx, new);
                    }
                }
            }
            snooze_lazy(&mut bo);
        }
    }

    fn note_insert(&self, t: &CTable<K, V>, idx: usize) {
        // Ordering: RELAXED — statistical estimate only.
        let n = t.stripe(idx).fetch_add(1, P::RELAXED) + 1;
        let span = OCCUPANCY_STRIPE.min(t.len());
        if n > (span * GROW_LOAD_FACTOR) as isize {
            self.try_begin_grow(t);
        }
    }

    fn note_remove(&self, t: &CTable<K, V>, idx: usize) {
        // Ordering: RELAXED — as in note_insert.
        t.stripe(idx).fetch_sub(1, P::RELAXED);
    }

    /// Publish a double-size destination (see `CacheHash::try_begin_grow`
    /// for the stale-descriptor argument). Requires the caller's pin.
    fn try_begin_grow(&self, t: &CTable<K, V>) {
        if self.resize.load().in_flight() {
            return;
        }
        let tp = t as *const CTable<K, V> as *mut CTable<K, V>;
        if self.root.load(P::ACQUIRE) != tp {
            return;
        }
        let new: *mut CTable<K, V> = Box::into_raw(Box::new(CTable::new(t.len() * 2)));
        let desc = ResizeState {
            old: tp as u64,
            new: new as u64,
            cursor: 0,
        };
        if self.resize.compare_exchange(ResizeState::default(), desc).is_err() {
            // SAFETY: never published.
            drop(unsafe { Box::from_raw(new) });
            return;
        }
        if self.root.load(P::ACQUIRE) != tp {
            if self.resize.compare_exchange(desc, ResizeState::default()).is_ok() {
                // SAFETY: unpublished again, never dereferenced.
                drop(unsafe { Box::from_raw(new) });
            }
            return;
        }
        // Descriptor published and still rooted: this grow is real.
        crate::counter!(ResizeGrowBegin);
        self.help_resize();
    }

    /// Claim and migrate one stripe (no-op when idle). Requires the pin.
    fn help_resize(&self) {
        let mut rs = self.resize.load();
        if !rs.in_flight() {
            return;
        }
        let root = self.root.load(P::ACQUIRE);
        if rs.old != root as u64 {
            return;
        }
        // SAFETY: old == root — live under the caller's pin.
        let old = unsafe { &*root };
        let len = old.len();
        let (start, end) = loop {
            if !rs.in_flight() || rs.old != root as u64 {
                return;
            }
            let c = rs.cursor as usize;
            if c >= len {
                return;
            }
            let end = (c + MIGRATION_STRIPE).min(len);
            match self.resize.compare_exchange(
                rs,
                ResizeState {
                    cursor: end as u64,
                    ..rs
                },
            ) {
                Ok(_) => {
                    crate::counter!(ResizeStripeClaim);
                    // A kill here is the dead-claimant scenario: the
                    // cursor has advanced past a stripe nobody will
                    // copy. `finish_resizes`'s sweep re-covers it.
                    crate::failpoint!(ResizeStripeClaim);
                    break (c, end);
                }
                Err(w) => rs = w,
            }
        };
        // SAFETY: claimed descriptor matched the root.
        let new = unsafe { &*(rs.new as *const CTable<K, V>) };
        for idx in start..end {
            self.migrate_bucket(old, idx, new);
        }
    }

    /// Seal-and-copy one source bucket (see `CacheHash::migrate_bucket`
    /// for the takeover/census argument — identical protocol on the
    /// tagged-word representation).
    fn migrate_bucket(&self, old: &CTable<K, V>, idx: usize, new: &CTable<K, V>) {
        let bucket = old.bucket(idx);
        // Ordering: ACQUIRE — the head is dereferenced during the copy.
        let mut raw = bucket.load(P::ACQUIRE);
        let mut bo = None;
        loop {
            if raw == FWD {
                // Already migrated and accounted (re-entry via
                // finish_resizes or the sweep).
                return;
            }
            if is_frozen(raw) {
                // Takeover: the sealing copier may be stalled or dead.
                if self.copy_frozen(bucket, raw, new) {
                    break; // our DONE transition: account below
                }
                return; // a rival's DONE transition accounted already
            }
            if is_closing(raw) {
                // Copy complete; a publisher died (or is racing us)
                // between CLOSING and DONE.
                if self.publish_done(bucket, raw) {
                    break;
                }
                return;
            }
            if raw == 0 {
                // Empty source: seal straight to DONE.
                // Ordering: RELEASE publishes the seal before any
                // reader's fall-through; ACQUIRE failure — the witness
                // is dereferenced on retry.
                match bucket.compare_exchange(0, FWD, P::RELEASE, P::ACQUIRE) {
                    Ok(_) => break,
                    Err(w) => {
                        raw = w;
                        snooze_lazy(&mut bo);
                    }
                }
                continue;
            }
            // Freeze the content (one-way: updates wait, finds read).
            // Ordering: RELEASE / ACQUIRE as above.
            match bucket.compare_exchange(raw, raw | FWD, P::RELEASE, P::ACQUIRE) {
                Ok(_) => {
                    // A kill here leaves the bucket FROZEN with no
                    // copier — the takeover arm above must recover it.
                    crate::failpoint!(ResizeSealFrozen);
                    if self.copy_frozen(bucket, raw | FWD, new) {
                        break;
                    }
                    return; // a takeover helper beat us to DONE
                }
                Err(w) => {
                    raw = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
        // Exactly one DONE transition per bucket reports it migrated.
        crate::counter!(ResizeBucketMigrate);
        // Ordering: AcqRel — the finisher's promotion happens-after
        // every copier's DONE publication.
        if old.migrated.fetch_add(1, Ordering::AcqRel) + 1 == old.len() {
            self.finish_resize(old);
        }
    }

    /// An update ran out of patience with a FROZEN bucket: locate the
    /// in-flight descriptor and help copy that one bucket out. No-op
    /// when the descriptor moved on.
    fn help_frozen_bucket(&self, t: &CTable<K, V>, idx: usize) {
        let rs = self.resize.load();
        let tp = t as *const CTable<K, V> as u64;
        if !rs.in_flight() || rs.old != tp || self.root.load(P::ACQUIRE) as u64 != tp {
            return;
        }
        crate::counter!(ResizeTakeover);
        // SAFETY: the descriptor matches the live root — `new` is the
        // live destination under the caller's pin.
        let new = unsafe { &*(rs.new as *const CTable<K, V>) };
        self.migrate_bucket(t, idx, new);
    }

    /// Copy a FROZEN bucket's (immutable) chain into the destination and
    /// race it through CLOSING to DONE — the census-fenced concurrent
    /// copy of `CacheHash::copy_frozen`. Returns whether *we* won DONE.
    fn copy_frozen(&self, bucket: &AtomicUsize, frozen: usize, new: &CTable<K, V>) -> bool {
        debug_assert!(is_frozen(frozen), "copy_frozen on an unsealed bucket");
        let addr = bucket as *const AtomicUsize as usize;
        {
            let _census = census::announce(addr);
            // Re-validate post-announce (the Dekker edge — see the
            // census module docs): any change means CLOSING or DONE,
            // and we must not write.
            // Ordering: ACQUIRE — the chain is dereferenced below; the
            // announce's SeqCst fence provides the store-load edge.
            if bucket.load(P::ACQUIRE) == frozen {
                let mut p = node_of::<K, V>(frozen);
                while !p.is_null() {
                    // SAFETY: frozen chain, region-pinned.
                    let n = unsafe { &*p };
                    self.copy_entry(new, n.key, n.value);
                    // A kill here unwinds the census guard — a rival
                    // re-runs the copy idempotently.
                    crate::failpoint!(ResizeCopyEntry);
                    p = n.next;
                }
            }
            // Guard dropped here: our destination writes are complete.
        }
        // Close the copier window. One CAS winner; losers fall through
        // to the publish race on the same (deterministic) value.
        // Ordering: RELEASE — orders the copies before the state change;
        // RELAXED failure (the witness is not dereferenced).
        let closing = frozen | CLOSING;
        let _ = bucket.compare_exchange(frozen, closing, P::RELEASE, P::RELAXED);
        self.publish_done(bucket, closing)
    }

    /// Drain straggling copiers off a CLOSING bucket, then race its
    /// CLOSING→DONE transition. Returns whether *we* won — the winner
    /// alone retires the drained chain.
    fn publish_done(&self, bucket: &AtomicUsize, closing: usize) -> bool {
        debug_assert!(is_closing(closing), "publish_done on a non-CLOSING word");
        let addr = bucket as *const AtomicUsize as usize;
        // Wait until no rival copier still announces this bucket (a
        // killed one's guard cleared on unwind) — the fence that keeps
        // every copy write pre-DONE.
        let mut bo = None;
        while census::rivals(addr) {
            snooze_lazy(&mut bo);
        }
        // Publish DONE — the generation-crossing point. A kill *before*
        // the CAS re-opens the publish window; after it, the accounting
        // in `migrate_bucket` is fault-free by construction.
        crate::failpoint!(ResizePublishDone);
        // Ordering: RELEASE — the copies happen-before any reader's
        // fall-through to the destination; RELAXED failure.
        if bucket
            .compare_exchange(closing, FWD, P::RELEASE, P::RELAXED)
            .is_err()
        {
            return false; // a rival published DONE (the image is immutable)
        }
        // Retire the drained chain through the region scheme — winner
        // only, exactly once per bucket, as ONE page batch (one retire
        // entry and one eventual orphan-lock acquisition per chain,
        // however long it was).
        let mut batch = pool::PageBatch::new();
        let mut p = node_of::<K, V>(closing);
        while !p.is_null() {
            // SAFETY: unlinked by the DONE transition; lagging
            // frozen-image readers are pinned, which keeps the whole
            // batch unrecycled until they unpin.
            let nx = unsafe { (*p).next };
            unsafe { batch.push(p) };
            p = nx;
        }
        // SAFETY: every pushed node is unlinked and unique.
        unsafe { S::retire_page(batch) };
        true
    }

    /// Insert-if-absent into the destination (no growth trigger — the
    /// descriptor is held; counters accumulate for the next cycle).
    fn copy_entry(&self, new: &CTable<K, V>, key: K, value: V) {
        let idx = bucket_for(&key, new.len());
        let bucket = new.bucket(idx);
        // Ordering: ACQUIRE — head dereferenced below.
        let mut raw = bucket.load(P::ACQUIRE);
        let fresh = pool::alloc_node(Node {
            key,
            value,
            next: std::ptr::null_mut(),
        });
        let mut bo = None;
        loop {
            debug_assert_eq!(raw & FWD, 0, "destination sealed mid-migration");
            let head = node_of::<K, V>(raw);
            if Self::chain_find(head, &key).is_some() {
                // SAFETY: never published — idempotence insurance.
                unsafe { pool::free_node_now(fresh) };
                return;
            }
            // SAFETY: unpublished, exclusively ours until the CAS wins.
            unsafe { (*fresh).next = head };
            // Ordering: RELEASE on success publishes the node's contents
            // before its address; ACQUIRE on failure — the witness head
            // is walked on retry.
            match bucket.compare_exchange(raw, fresh as usize, P::RELEASE, P::ACQUIRE) {
                Ok(_) => {
                    // Ordering: RELAXED — estimate.
                    new.stripe(idx).fetch_add(1, P::RELAXED);
                    return;
                }
                Err(w) => {
                    raw = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    /// Promote the destination, clear the descriptor, retire the source
    /// (run by the unique finishing copier).
    fn finish_resize(&self, old: &CTable<K, V>) {
        let rs = self.resize.load();
        let op = old as *const CTable<K, V> as *mut CTable<K, V>;
        debug_assert!(rs.in_flight() && rs.old == op as u64);
        let new = rs.new as *mut CTable<K, V>;
        // Ordering: ACQREL CAS — the release half publishes the fully
        // populated destination to readers' ACQUIRE root loads.
        let swung = self
            .root
            .compare_exchange(op, new, P::ACQREL, P::ACQUIRE)
            .is_ok();
        debug_assert!(swung, "root moved before the finisher");
        let mut cur = rs;
        while cur.in_flight() && cur.old == op as u64 {
            match self.resize.compare_exchange(cur, ResizeState::default()) {
                Ok(_) => break,
                Err(w) => cur = w,
            }
        }
        self.generations.fetch_add(1, Ordering::AcqRel);
        crate::counter!(ResizeFinish);
        // SAFETY: unlinked from the root and the descriptor; unique.
        unsafe { S::retire_box(op) };
    }
}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> ConcurrentMap<K, V> for Chaining<K, V, S> {
    fn find(&self, key: K) -> Option<V> {
        let _g = S::pin();
        let mut t = self.root_table();
        loop {
            // Ordering: ACQUIRE — pairs with the RELEASE install CAS so
            // node contents are visible before the walk; the pin (not
            // this load) keeps the nodes alive.
            let raw = t.bucket(bucket_for(&key, t.len())).load(P::ACQUIRE);
            if raw == FWD {
                // DONE: fall through old → new, lock-free.
                t = self.table_after(t);
                continue;
            }
            // FROZEN (`p|1`) reads its content in place — the frozen
            // image is authoritative until the DONE transition.
            return Self::chain_find(node_of::<K, V>(raw), &key);
        }
    }

    fn insert(&self, key: K, value: V) -> bool {
        let _g = S::pin();
        // Updates pay the incremental-migration toll: one stripe.
        self.help_resize();
        let mut t = self.root_table();
        let mut idx = bucket_for(&key, t.len());
        let mut bucket = t.bucket(idx);
        // Ordering: ACQUIRE — the head is dereferenced below.
        let mut raw = bucket.load(P::ACQUIRE);
        // The chain suffix already proven free of `key`: nodes are
        // immutable after publish and region-pinned (no address reuse
        // within this op), so pointer equality identifies the proven
        // suffix and the retry walks only the new prefix.
        let mut searched: *mut Node<K, V> = std::ptr::null_mut();
        let mut have_searched = false;
        // The spare (never-published) pool node from a failed CAS is
        // reused on retry and freed on a duplicate hit.
        let mut spare: *mut Node<K, V> = std::ptr::null_mut();
        let mut bo = None;
        // Bounded patience with a FROZEN bucket before helping copy it.
        let mut frozen_waits = 0u32;
        loop {
            if raw & FWD != 0 {
                if raw != FWD {
                    // FROZEN/CLOSING: the copier's window is chain-
                    // bounded — unless the copier died in it. Wait a
                    // bounded number of beats, then help (idempotent
                    // takeover via `help_frozen_bucket`).
                    crate::counter!(ResizeFrozenWait);
                    frozen_waits += 1;
                    if frozen_waits > FROZEN_PATIENCE {
                        frozen_waits = 0;
                        self.help_frozen_bucket(t, idx);
                    } else {
                        snooze_lazy(&mut bo);
                    }
                    raw = bucket.load(P::ACQUIRE);
                    continue;
                }
                // DONE: hop generations.
                t = self.table_after(t);
                idx = bucket_for(&key, t.len());
                bucket = t.bucket(idx);
                raw = bucket.load(P::ACQUIRE);
                have_searched = false;
                continue;
            }
            let head = node_of::<K, V>(raw);
            // Duplicate check, skipping the already-proven suffix.
            let mut p = head;
            while !p.is_null() && !(have_searched && p == searched) {
                // SAFETY: region-pinned traversal of immutable nodes.
                let n = unsafe { &*p };
                if n.key == key {
                    if !spare.is_null() {
                        // SAFETY: never published.
                        unsafe { pool::free_node_now(spare) };
                    }
                    return false;
                }
                p = n.next;
            }
            searched = head;
            have_searched = true;
            let fresh = if spare.is_null() {
                pool::alloc_node(Node {
                    key,
                    value,
                    next: head,
                })
            } else {
                let f = spare;
                spare = std::ptr::null_mut();
                // SAFETY: our never-published spare — exclusive.
                unsafe { (*f).next = head };
                f
            };
            // Ordering: RELEASE on success publishes the node's contents
            // before its address; ACQUIRE on failure — the witness head
            // is walked on retry (no re-load).
            match bucket.compare_exchange(raw, fresh as usize, P::RELEASE, P::ACQUIRE) {
                Ok(_) => {
                    self.note_insert(t, idx);
                    return true;
                }
                Err(w) => {
                    // The node stays unpublished; keep it for the retry.
                    spare = fresh;
                    raw = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn remove(&self, key: K) -> bool {
        let _g = S::pin();
        // Updates pay the incremental-migration toll: one stripe.
        self.help_resize();
        let mut t = self.root_table();
        let mut idx = bucket_for(&key, t.len());
        let mut bucket = t.bucket(idx);
        // Ordering: ACQUIRE — the head is dereferenced below.
        let mut raw = bucket.load(P::ACQUIRE);
        let mut bo = None;
        // Bounded patience with a FROZEN bucket before helping copy it.
        let mut frozen_waits = 0u32;
        loop {
            if raw & FWD != 0 {
                if raw != FWD {
                    crate::counter!(ResizeFrozenWait);
                    frozen_waits += 1;
                    if frozen_waits > FROZEN_PATIENCE {
                        frozen_waits = 0;
                        self.help_frozen_bucket(t, idx);
                    } else {
                        snooze_lazy(&mut bo);
                    }
                    raw = bucket.load(P::ACQUIRE);
                    continue;
                }
                t = self.table_after(t);
                idx = bucket_for(&key, t.len());
                bucket = t.bucket(idx);
                raw = bucket.load(P::ACQUIRE);
                continue;
            }
            let head = node_of::<K, V>(raw);
            // Find the victim, collecting the prefix to path-copy.
            let mut prefix: Vec<(K, V)> = Vec::new();
            let mut p = head;
            let mut suffix: *mut Node<K, V> = std::ptr::null_mut();
            let mut found = false;
            while !p.is_null() {
                // SAFETY: region-pinned.
                let n = unsafe { &*p };
                if n.key == key {
                    found = true;
                    suffix = n.next;
                    break;
                }
                prefix.push((n.key, n.value));
                p = n.next;
            }
            if !found {
                return false;
            }
            let victim = p;
            let mut new_head = suffix;
            for &(k, v) in prefix.iter().rev() {
                new_head = pool::alloc_node(Node {
                    key: k,
                    value: v,
                    next: new_head,
                });
            }
            // Ordering: RELEASE on success publishes the path copies;
            // ACQUIRE on failure — the witness head is walked on retry.
            match bucket.compare_exchange(raw, new_head as usize, P::RELEASE, P::ACQUIRE) {
                Ok(_) => {
                    // SAFETY: victim + original prefix unlinked by the
                    // CAS; pool-retired so slots recycle after the
                    // region grace period.
                    unsafe {
                        pool::retire_node::<S, _>(victim);
                        let mut q = head;
                        while q != victim {
                            let nx = (*q).next;
                            pool::retire_node::<S, _>(q);
                            q = nx;
                        }
                    }
                    self.note_remove(t, idx);
                    return true;
                }
                Err(w) => {
                    let mut q = new_head;
                    while q != suffix {
                        // SAFETY: never published.
                        let nx = unsafe { (*q).next };
                        unsafe { pool::free_node_now(q) };
                        q = nx;
                    }
                    raw = w;
                    snooze_lazy(&mut bo);
                }
            }
        }
    }

    fn map_name(&self) -> &'static str {
        "Chaining(no-inline)"
    }

    fn capacity(&self) -> usize {
        let _g = S::pin();
        self.root_table().len()
    }

    fn occupancy(&self) -> usize {
        let _g = S::pin();
        self.root_table()
            .stripes
            .iter()
            // Ordering: RELAXED — estimate.
            .map(|s| s.load(P::RELAXED))
            .sum::<isize>()
            .max(0) as usize
    }
}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Drop for Chaining<K, V, S> {
    fn drop(&mut self) {
        let root = *self.root.get_mut();
        let rs = self.resize.load();
        // Exclusive (&mut self) — see CacheHash::drop.
        unsafe {
            if rs.in_flight() {
                debug_assert_eq!(rs.old, root as u64, "descriptor of a foreign root at drop");
                drop_ctable(rs.new as *mut CTable<K, V>);
            }
            drop_ctable(root);
        }
        S::flush_thread_bag();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_basic() {
        let t: Chaining = Chaining::new(64);
        assert!(t.insert(5, 50));
        assert!(!t.insert(5, 51));
        assert_eq!(t.find(5), Some(50));
        assert!(t.remove(5));
        assert_eq!(t.find(5), None);
    }

    #[test]
    fn test_generic_multiword() {
        let t: Chaining<Words<3>, Words<2>> = Chaining::new(8);
        assert!(t.insert(Words([1, 2, 3]), Words([4, 5])));
        assert!(!t.insert(Words([1, 2, 3]), Words([0, 0])));
        assert_eq!(t.find(Words([1, 2, 3])), Some(Words([4, 5])));
        assert_eq!(t.find(Words([3, 2, 1])), None);
        assert!(t.remove(Words([1, 2, 3])));
        assert_eq!(t.find(Words([1, 2, 3])), None);
    }

    #[test]
    fn test_collisions_and_interior_delete() {
        let t: Chaining = Chaining::new(2);
        for k in 0..50u64 {
            assert!(t.insert(k, k + 100));
        }
        for k in (0..25u64).map(|i| 48 - 2 * i) {
            assert!(t.remove(k));
        }
        for k in 0..50u64 {
            let want = if k % 2 == 0 { None } else { Some(k + 100) };
            assert_eq!(t.find(k), want);
        }
    }

    #[test]
    fn test_grow_from_tiny_capacity_single_thread() {
        // Deterministic growth mirror of the CacheHash case: a
        // capacity-2 baseline table absorbing 5k inserts must double
        // repeatedly with no lost or duplicated keys.
        let t: Chaining = Chaining::new(2);
        assert_eq!(t.capacity(), 2);
        for k in 0..5_000u64 {
            assert!(t.insert(k, !k));
        }
        t.finish_resizes();
        assert!(!t.resize_in_flight());
        assert!(t.capacity() >= 1024, "capacity stuck at {}", t.capacity());
        assert!(t.generation() >= 9, "only {} doublings", t.generation());
        for k in 0..5_000u64 {
            assert_eq!(t.find(k), Some(!k), "key {k}");
            assert!(t.remove(k), "lost key {k}");
            assert!(!t.remove(k), "duplicated key {k}");
        }
    }

    #[test]
    fn test_concurrent_mixed() {
        let t: Arc<Chaining> = Arc::new(Chaining::new(256));
        let handles: Vec<_> = (0..4)
            .map(|tix| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let base = tix as u64 * 1_000_000;
                    for i in 0..2_000u64 {
                        assert!(t.insert(base + i, i));
                        if i % 2 == 0 {
                            assert!(t.remove(base + i));
                        }
                    }
                    for i in 0..2_000u64 {
                        let want = if i % 2 == 0 { None } else { Some(i) };
                        assert_eq!(t.find(base + i), want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
