//! Chaining — the paper's no-inlining baseline (§4 first paragraph,
//! "Chaining" series in Fig 3): identical algorithm to CacheHash but the
//! bucket is a plain atomic *pointer* to the first link, so every
//! non-empty find pays at least one extra dependent cache miss.
//! Generic over the same key/value types as [`CacheHash`](super::CacheHash),
//! and over the same region-grained reclamation parameter (epoch-based;
//! see `smr` for why hazard pointers are rejected at the type level).

use std::marker::PhantomData;
use std::sync::atomic::{AtomicPtr, Ordering};

use super::{bucket_for, table_capacity, ConcurrentMap};
use crate::atomics::AtomicValue;
use crate::smr::{Epoch, RegionSmr};
use crate::util::CachePadded;

struct Node<K, V> {
    key: K,
    value: V,
    next: *mut Node<K, V>,
}

pub struct Chaining<K: AtomicValue = u64, V: AtomicValue = u64, S: RegionSmr = Epoch> {
    buckets: Box<[CachePadded<AtomicPtr<Node<K, V>>>]>,
    _smr: PhantomData<fn() -> S>,
}

// SAFETY: mutations via CAS on bucket heads; nodes immutable + region SMR.
unsafe impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Send for Chaining<K, V, S> {}
unsafe impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Sync for Chaining<K, V, S> {}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Chaining<K, V, S> {
    pub fn new(n: usize) -> Self {
        let cap = table_capacity(n);
        Self {
            buckets: (0..cap)
                .map(|_| CachePadded::new(AtomicPtr::new(std::ptr::null_mut())))
                .collect(),
            _smr: PhantomData,
        }
    }

    #[inline]
    fn bucket(&self, key: &K) -> &AtomicPtr<Node<K, V>> {
        &self.buckets[bucket_for(key, self.buckets.len())]
    }

    #[inline]
    fn chain_find(mut p: *mut Node<K, V>, key: &K) -> Option<V> {
        while !p.is_null() {
            // SAFETY: region-pinned by caller.
            let n = unsafe { &*p };
            if n.key == *key {
                return Some(n.value);
            }
            p = n.next;
        }
        None
    }
}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> ConcurrentMap<K, V> for Chaining<K, V, S> {
    fn find(&self, key: K) -> Option<V> {
        let _g = S::pin();
        Self::chain_find(self.bucket(&key).load(Ordering::SeqCst), &key)
    }

    fn insert(&self, key: K, value: V) -> bool {
        loop {
            let _g = S::pin();
            let bucket = self.bucket(&key);
            let head = bucket.load(Ordering::SeqCst);
            if Self::chain_find(head, &key).is_some() {
                return false;
            }
            let node = Box::into_raw(Box::new(Node {
                key,
                value,
                next: head,
            }));
            if bucket
                .compare_exchange(head, node, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                return true;
            }
            // SAFETY: never published.
            drop(unsafe { Box::from_raw(node) });
        }
    }

    fn remove(&self, key: K) -> bool {
        loop {
            let _g = S::pin();
            let bucket = self.bucket(&key);
            let head = bucket.load(Ordering::SeqCst);
            // Find the victim, collecting the prefix to path-copy.
            let mut prefix: Vec<(K, V)> = Vec::new();
            let mut p = head;
            let mut suffix: *mut Node<K, V> = std::ptr::null_mut();
            let mut found = false;
            while !p.is_null() {
                // SAFETY: region-pinned.
                let n = unsafe { &*p };
                if n.key == key {
                    found = true;
                    suffix = n.next;
                    break;
                }
                prefix.push((n.key, n.value));
                p = n.next;
            }
            if !found {
                return false;
            }
            let victim = p;
            let mut new_head = suffix;
            for &(k, v) in prefix.iter().rev() {
                new_head = Box::into_raw(Box::new(Node {
                    key: k,
                    value: v,
                    next: new_head,
                }));
            }
            if bucket
                .compare_exchange(head, new_head, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                // SAFETY: victim + original prefix unlinked by the CAS.
                unsafe {
                    S::retire_box(victim);
                    let mut q = head;
                    while q != victim {
                        let nx = (*q).next;
                        S::retire_box(q);
                        q = nx;
                    }
                }
                return true;
            }
            let mut q = new_head;
            while q != suffix {
                // SAFETY: never published.
                let b = unsafe { Box::from_raw(q) };
                q = b.next;
            }
        }
    }

    fn map_name(&self) -> &'static str {
        "Chaining(no-inline)"
    }
}

impl<K: AtomicValue, V: AtomicValue, S: RegionSmr> Drop for Chaining<K, V, S> {
    fn drop(&mut self) {
        for b in self.buckets.iter() {
            let mut p = b.load(Ordering::Relaxed);
            while !p.is_null() {
                // SAFETY: exclusive in Drop.
                let n = unsafe { Box::from_raw(p) };
                p = n.next;
            }
        }
        S::flush_thread_bag();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_basic() {
        let t: Chaining = Chaining::new(64);
        assert!(t.insert(5, 50));
        assert!(!t.insert(5, 51));
        assert_eq!(t.find(5), Some(50));
        assert!(t.remove(5));
        assert_eq!(t.find(5), None);
    }

    #[test]
    fn test_generic_multiword() {
        let t: Chaining<Words<3>, Words<2>> = Chaining::new(8);
        assert!(t.insert(Words([1, 2, 3]), Words([4, 5])));
        assert!(!t.insert(Words([1, 2, 3]), Words([0, 0])));
        assert_eq!(t.find(Words([1, 2, 3])), Some(Words([4, 5])));
        assert_eq!(t.find(Words([3, 2, 1])), None);
        assert!(t.remove(Words([1, 2, 3])));
        assert_eq!(t.find(Words([1, 2, 3])), None);
    }

    #[test]
    fn test_collisions_and_interior_delete() {
        let t: Chaining = Chaining::new(2);
        for k in 0..50u64 {
            assert!(t.insert(k, k + 100));
        }
        for k in (0..25u64).map(|i| 48 - 2 * i) {
            assert!(t.remove(k));
        }
        for k in 0..50u64 {
            let want = if k % 2 == 0 { None } else { Some(k + 100) };
            assert_eq!(t.find(k), want);
        }
    }

    #[test]
    fn test_concurrent_mixed() {
        let t: Arc<Chaining> = Arc::new(Chaining::new(256));
        let handles: Vec<_> = (0..4)
            .map(|tix| {
                let t = Arc::clone(&t);
                std::thread::spawn(move || {
                    let base = tix as u64 * 1_000_000;
                    for i in 0..2_000u64 {
                        assert!(t.insert(base + i, i));
                        if i % 2 == 0 {
                            assert!(t.remove(base + i));
                        }
                    }
                    for i in 0..2_000u64 {
                        let want = if i % 2 == 0 { None } else { Some(i) };
                        assert_eq!(t.find(base + i), want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
