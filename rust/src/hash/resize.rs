//! The one resize engine (grow **and** shrink), shared by both hash
//! tables.
//!
//! PR 5 gave `CacheHash` and `Chaining` twin online-resize protocols and
//! PR 8's crash tolerance deepened the duplication to ~2× the protocol's
//! full surface. This module is the single remaining copy: the
//! descriptor lifecycle ([`try_begin_resize`] → [`help_resize`] →
//! `migrate_bucket` → `finish_resize` and the [`finish_resizes`] sweep),
//! stripe claim/accounting, the FROZEN-patience + census/CLOSING
//! takeover, and drained-table retirement — parameterized by the
//! [`ResizeTable`] trait so each table keeps only what is genuinely its
//! own: the bucket word encoding (a big-atomic [`Link`](super::Link) vs
//! a tagged pointer word), `copy_entry` (insert-if-absent into the
//! destination), and chain retirement.
//!
//! ## The protocol (direction-agnostic)
//!
//! A migration is a [`ResizeState`] descriptor — (old table, new table,
//! stripe cursor) — published through a `SeqLock` big atomic. Every
//! update entering the map claims one stripe of source buckets with the
//! witnessing `compare_exchange` on the cursor and migrates it:
//!
//! 1. **seal** — CAS the source bucket to its FROZEN image. Finds read
//!    the frozen content in place; updates wait [`FROZEN_PATIENCE`]
//!    beats and then take the copy over themselves.
//! 2. **copy** — re-hash every entry of the (immutable) frozen image
//!    into the destination, insert-if-absent, under a
//!    [`census`](super::census) announcement (announce → re-validate
//!    FROZEN → copy; RAII-cleared on unwind).
//! 3. **CLOSING** — no new copier joins; the publisher drains rival
//!    copiers (the Dekker store-load fence that keeps every destination
//!    write pre-DONE).
//! 4. **DONE** — one CAS winner retires the drained chain and accounts
//!    the bucket; the last bucket's winner promotes the destination.
//!
//! Nothing above cares whether the destination is larger or smaller —
//! `bucket_for` re-hashes into whatever the destination's length is. The
//! **direction** lives entirely in the triggers:
//!
//! * **grow** — a per-stripe occupancy estimate crosses
//!   [`GROW_LOAD_FACTOR`] (load factor > 2 locally): publish a
//!   double-size destination.
//! * **shrink** — the *global* occupancy estimate falls below
//!   `capacity / `[`SHRINK_FACTOR`] (load factor < 1/4) and half the
//!   capacity still respects the construction-time floor: publish a
//!   half-size destination.
//!
//! ## Hysteresis (why grow/shrink cannot oscillate)
//!
//! The two thresholds leave a 4× churn band between them, in both
//! directions:
//!
//! * After a **shrink** the load factor is at most `2/SHRINK_FACTOR` =
//!   1/2 (it was < 1/4 of the old capacity, which is 2× the new). To
//!   grow, some stripe must exceed load factor [`GROW_LOAD_FACTOR`] = 2
//!   — the table must roughly **quadruple** its live entries first.
//! * After a **grow** the triggering stripe's load factor is ~1 (it was
//!   just over 2 at half the capacity). To shrink, the *global* load
//!   factor must fall below 1/4 — roughly **4× removal** first.
//!
//! Each completed migration therefore moves the occupancy at least a
//! factor of 4 away from the opposite trigger; alternating bursts inside
//! the band fire neither (`test_shrink_oscillation_guard` in the
//! linearizability suite pins this).
//!
//! ## Self-convergence
//!
//! Updates drive migration incrementally, so a table that goes quiet
//! half-migrated would historically stay half-migrated. Two hooks close
//! that: [`finish_resizes`] (drive the in-flight migration to
//! completion, sweeping stripes whose claimant died), and the
//! [`Maintain`] trait + [`BackgroundMigrator`] — a maintenance thread
//! that periodically evaluates the shrink trigger and drains any
//! in-flight migration with **zero foreground operations**.
//!
//! ## Per-op stripe-grain adaptation
//!
//! The cursor-claim grain starts at [`MIGRATION_STRIPE`] and adapts per
//! thread: every lost claim CAS halves it (down to [`MIN_STRIPE`] — more
//! claimants, finer slices, less wasted double-copy work), and a
//! first-try win doubles it (up to [`MAX_STRIPE`] — an uncontended
//! copier takes bigger bites). The *occupancy* grain
//! ([`OCCUPANCY_STRIPE`]) never adapts: accounting must stay stable.
//!
//! ## What a new table must provide
//!
//! Implement [`ResizeTable`]: the five state-cell accessors, table
//! alloc/len/stripe/retire plumbing, the bucket load/CAS + image
//! predicates for the FROZEN/CLOSING/DONE encoding, and the two real
//! hooks — `copy_image` (copy every entry of a frozen image into the
//! destination, insert-if-absent, with a `ResizeCopyEntry` failpoint
//! between entries) and `retire_image` (retire a drained image's chain,
//! winner-only). Everything else — triggers, claims, seals, takeover,
//! retirement, shrink, background convergence — is inherited.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicIsize, AtomicPtr, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::{census, ResizeState};
use crate::atomics::SeqLock;
use crate::util::backoff::snooze_lazy;
use crate::util::ordering::{DefaultPolicy as P, OrderingPolicy};

/// Source buckets migrated per helper claim (the starting grain;
/// adapts per thread between [`MIN_STRIPE`] and [`MAX_STRIPE`]).
pub const MIGRATION_STRIPE: usize = 64;

/// Buckets covered by one occupancy counter (the trigger estimators'
/// grain). Fixed — unlike the migration grain, accounting cannot adapt.
pub const OCCUPANCY_STRIPE: usize = 64;

/// Grow when a stripe's live-entry estimate exceeds this multiple of its
/// bucket count (the paper's design point is load factor one; beyond ~2
/// the chains dominate).
pub const GROW_LOAD_FACTOR: usize = 2;

/// Shrink when the global live-entry estimate times this factor is below
/// the bucket count (load factor < 1/4). Together with
/// [`GROW_LOAD_FACTOR`] this leaves a 4× hysteresis band in each
/// direction — see the module docs for the no-oscillation argument.
pub const SHRINK_FACTOR: usize = 4;

/// Snoozes an update grants a FROZEN bucket's copier before copying the
/// bucket out itself (the copier may be preempted — or dead).
pub const FROZEN_PATIENCE: u32 = 16;

/// Smallest adaptive claim grain (a thread drowning in lost claim CASes
/// takes slices this fine).
pub const MIN_STRIPE: usize = 8;

/// Largest adaptive claim grain (an uncontended copier takes bites this
/// big).
pub const MAX_STRIPE: usize = 256;

thread_local! {
    /// This thread's adaptive cursor-claim grain.
    static STRIPE_GRAIN: Cell<usize> = const { Cell::new(MIGRATION_STRIPE) };
}

/// This thread's current adaptive claim grain (tests/telemetry).
pub fn stripe_grain() -> usize {
    STRIPE_GRAIN.with(Cell::get)
}

/// Which way an in-flight migration is headed (derived from the two
/// table lengths — the descriptor itself is direction-blind).
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Direction {
    Grow,
    Shrink,
}

#[inline]
fn direction(old_len: usize, new_len: usize) -> Direction {
    if new_len >= old_len {
        Direction::Grow
    } else {
        Direction::Shrink
    }
}

/// The per-representation surface of the resize engine. Implemented by
/// each table type (`CacheHash`, `Chaining`); the engine's free
/// functions own everything protocol-shaped.
///
/// # Safety
///
/// Implementors must uphold the engine's aliasing contract:
///
/// * Every method is called under the table's region pin (`RegionSmr`),
///   and tables referenced by a root-matching descriptor stay live for
///   the pin's lifetime (`retire_drained_table` must go through the
///   region scheme, never free directly).
/// * `Image` is a bitwise snapshot of one bucket: `load_bucket` /
///   `cas_bucket` must be atomic, `cas_bucket`'s failure must return the
///   witnessed current image, and the FROZEN/CLOSING/DONE predicates and
///   constructors must agree with the encoding `cas_bucket` installs
///   (exactly one predicate true per sealed image; `sealed`/`closing_of`
///   preserve content).
/// * `copy_image` must be idempotent under concurrent callers copying
///   the *same* immutable image (insert-if-absent), and `retire_image`
///   must be safe to call exactly once per bucket, by the DONE winner,
///   on an image whose chain the DONE transition just unlinked.
/// * `alloc_table` returns a fresh, empty, never-shared table;
///   `free_unpublished_table` is only called on tables never published
///   through the descriptor.
pub unsafe trait ResizeTable {
    /// One generation of buckets.
    type Table;
    /// A bitwise snapshot of one bucket's contents.
    type Image: Copy + PartialEq;

    // -- state cells -------------------------------------------------------
    /// The migration descriptor cell.
    fn resize_cell(&self) -> &SeqLock<ResizeState>;
    /// The live-generation root pointer.
    fn root_cell(&self) -> &AtomicPtr<Self::Table>;
    /// Completed grow migrations.
    fn grow_cell(&self) -> &AtomicUsize;
    /// Completed shrink migrations.
    fn shrink_cell(&self) -> &AtomicUsize;
    /// The construction-time capacity: shrink never goes below this.
    fn floor(&self) -> usize;

    // -- table plumbing ----------------------------------------------------
    /// A fresh empty table of `cap` buckets (heap-allocated, unshared).
    fn alloc_table(&self, cap: usize) -> *mut Self::Table;
    /// Free a table that was never published (lost publish race /
    /// retracted stale descriptor).
    ///
    /// # Safety
    /// `t` must come from `alloc_table` and never have been reachable
    /// through the descriptor or the root.
    unsafe fn free_unpublished_table(&self, t: *mut Self::Table);
    /// Retire a fully-drained source table through the region scheme.
    ///
    /// # Safety
    /// `t` must be unlinked from both the root and the descriptor, with
    /// every bucket DONE (chains already retired at their transitions).
    unsafe fn retire_drained_table(&self, t: *mut Self::Table);
    fn len_of(t: &Self::Table) -> usize;
    /// Buckets sealed DONE; reaching `len_of` completes the migration.
    fn migrated_of(t: &Self::Table) -> &AtomicUsize;
    /// The occupancy-estimate counter covering bucket `idx`.
    fn stripe_of(t: &Self::Table, idx: usize) -> &AtomicIsize;
    /// Sum of all stripe estimates (may be transiently negative under
    /// racing insert/remove pairs).
    fn occupancy_of(t: &Self::Table) -> isize;

    // -- bucket ops --------------------------------------------------------
    fn load_bucket(t: &Self::Table, idx: usize) -> Self::Image;
    /// Atomic bucket CAS; `Err` carries the witnessed current image.
    fn cas_bucket(
        t: &Self::Table,
        idx: usize,
        cur: Self::Image,
        new: Self::Image,
    ) -> Result<(), Self::Image>;
    /// Stable address of the bucket cell — the census key.
    fn bucket_addr(t: &Self::Table, idx: usize) -> usize;

    // -- image predicates / constructors ------------------------------------
    /// Sealed empty: contents live in the next generation.
    fn is_done(img: Self::Image) -> bool;
    /// Sealed with content, copier window open.
    fn is_frozen(img: Self::Image) -> bool;
    /// Sealed with content, copier window closed (publisher draining).
    fn is_closing(img: Self::Image) -> bool;
    /// Unsealed and empty.
    fn is_empty_img(img: Self::Image) -> bool;
    /// `img` with the FROZEN seal added (content preserved).
    fn sealed(img: Self::Image) -> Self::Image;
    /// A FROZEN `img` with the CLOSING mark added (content preserved).
    fn closing_of(img: Self::Image) -> Self::Image;
    /// The DONE sentinel.
    fn done_img() -> Self::Image;

    // -- the genuinely distinct parts ---------------------------------------
    /// Copy every entry of the (immutable) frozen image into `new`,
    /// insert-if-absent, firing `failpoint!(ResizeCopyEntry)` between
    /// entries. Idempotent under concurrent copiers of the same image.
    fn copy_image(&self, new: &Self::Table, img: Self::Image);
    /// Retire the drained chain of a DONE'd image (winner-only, once per
    /// bucket).
    ///
    /// # Safety
    /// Caller must be the unique CLOSING→DONE transition winner for the
    /// bucket this image was loaded from.
    unsafe fn retire_image(&self, img: Self::Image);
}

/// The live root table. Callers must hold the region pin: drained tables
/// are only region-retired, so the reference stays valid for the pin's
/// lifetime even across concurrent resizes.
#[inline]
pub fn root_table<E: ResizeTable>(e: &E) -> &E::Table {
    // Ordering: ACQUIRE — pairs with the RELEASE root swing in
    // `finish_resize` so the promoted table's contents are visible.
    unsafe { &*e.root_cell().load(P::ACQUIRE) }
}

/// The table a DONE seal mark in `t` forwards to: the in-flight
/// migration's destination when the descriptor matches `t` *and* the
/// root, else the (necessarily newer) root. Requires the caller's pin.
pub fn table_after<'e, E: ResizeTable>(e: &'e E, t: &E::Table) -> &'e E::Table {
    let rs = e.resize_cell().load();
    // Ordering: ACQUIRE — as in `root_table`.
    let root = e.root_cell().load(P::ACQUIRE);
    let tp = t as *const E::Table as u64;
    if rs.in_flight() && rs.old == root as u64 && rs.old == tp {
        // SAFETY: the descriptor matches the live root, so `new` is the
        // live in-flight destination — pin-protected like every table.
        unsafe { &*(rs.new as *const E::Table) }
    } else {
        // The migration that sealed `t` has completed (the root is swung
        // before the descriptor is cleared), or a later one is in
        // flight: restart from the root, which is strictly newer than
        // `t`.
        // SAFETY: root is live under the caller's pin.
        unsafe { &*root }
    }
}

/// Account a successful insert into `t`'s stripe estimate and trigger a
/// grow when the stripe crosses the load-factor threshold. Requires the
/// caller's pin.
pub fn note_insert<E: ResizeTable>(e: &E, t: &E::Table, idx: usize) {
    // Ordering: RELAXED — the stripe counters are a statistical
    // estimate; nothing synchronizes through them.
    let n = E::stripe_of(t, idx).fetch_add(1, P::RELAXED) + 1;
    let span = OCCUPANCY_STRIPE.min(E::len_of(t));
    if n > (span * GROW_LOAD_FACTOR) as isize {
        try_begin_resize(e, t, E::len_of(t) * 2);
    }
}

/// Account a successful remove and evaluate the shrink trigger — but
/// only on exact downward crossings of the per-stripe shrink estimate
/// (`span/SHRINK_FACTOR` or zero), so the O(#stripes) global sum runs
/// O(1) times per stripe per drain, not per op. Requires the caller's
/// pin.
pub fn note_remove<E: ResizeTable>(e: &E, t: &E::Table, idx: usize) {
    // Ordering: RELAXED — as in note_insert.
    let n = E::stripe_of(t, idx).fetch_sub(1, P::RELAXED) - 1;
    let span = OCCUPANCY_STRIPE.min(E::len_of(t));
    if n == (span / SHRINK_FACTOR) as isize || n == 0 {
        try_begin_shrink(e, t);
    }
}

/// Publish a half-size destination when the global occupancy estimate is
/// below `capacity / SHRINK_FACTOR` and the halved capacity respects the
/// construction floor. Safe to call any time (maintenance threads call
/// it unconditionally); every condition is re-checked. Requires the
/// caller's pin.
pub fn try_begin_shrink<E: ResizeTable>(e: &E, t: &E::Table) {
    let cap = E::len_of(t);
    let target = cap / 2;
    if target < e.floor() || target < 2 {
        return; // never below what the user asked for
    }
    let occ = E::occupancy_of(t).max(0) as usize;
    if occ * SHRINK_FACTOR >= cap {
        return; // inside the hysteresis band
    }
    try_begin_resize(e, t, target);
}

/// Publish a `new_cap`-bucket destination for `t` if no migration is in
/// flight and `t` is still the root (the direction falls out of
/// `new_cap` vs `t`'s length). Requires the caller's pin.
pub fn try_begin_resize<E: ResizeTable>(e: &E, t: &E::Table, new_cap: usize) {
    if e.resize_cell().load().in_flight() {
        return;
    }
    let tp = t as *const E::Table as *mut E::Table;
    // Only the root resizes; a mid-migration destination resizes after
    // promotion.
    if e.root_cell().load(P::ACQUIRE) != tp {
        return;
    }
    let new = e.alloc_table(new_cap);
    let desc = ResizeState {
        old: tp as u64,
        new: new as u64,
        cursor: 0,
    };
    if e.resize_cell().compare_exchange(ResizeState::default(), desc).is_err() {
        // Lost the publish race to another resizer.
        // SAFETY: never published.
        unsafe { e.free_unpublished_table(new) };
        return;
    }
    if e.root_cell().load(P::ACQUIRE) != tp {
        // A full resize completed between our root check and the
        // publish: the descriptor is stale. Helpers ignore descriptors
        // whose `old` is not the root (and `t` cannot be freed while we
        // are pinned, so its address cannot be recycled into a new
        // root), so a successful exact retract proves the fresh table is
        // still unreferenced.
        if e.resize_cell().compare_exchange(desc, ResizeState::default()).is_ok() {
            // SAFETY: unpublished again, never dereferenced.
            unsafe { e.free_unpublished_table(new) };
        }
        return;
    }
    // Descriptor published and still rooted: this resize is real.
    match direction(E::len_of(t), new_cap) {
        Direction::Grow => {
            crate::counter!(ResizeGrowBegin);
        }
        Direction::Shrink => {
            crate::counter!(ResizeShrinkBegin);
        }
    }
    // Kick-start: migrate the first stripe ourselves.
    help_resize(e);
}

/// Claim and migrate one stripe of the in-flight resize (no-op when
/// idle), adapting this thread's claim grain: halve on every lost claim
/// CAS, double on a first-try win. Requires the caller's pin.
pub fn help_resize<E: ResizeTable>(e: &E) {
    let mut rs = e.resize_cell().load();
    if !rs.in_flight() {
        return;
    }
    let root = e.root_cell().load(P::ACQUIRE);
    if rs.old != root as u64 {
        return; // stale descriptor (retraction pending) or finishing
    }
    // SAFETY: old == root — live under the caller's pin.
    let old = unsafe { &*root };
    let len = E::len_of(old);
    // SAFETY: while `old` is the root and the descriptor matches it,
    // `new` is the live destination (it cannot be retired before the
    // descriptor clears, which our in-flight checks below detect).
    let new = unsafe { &*(rs.new as *const E::Table) };
    let dir = direction(len, E::len_of(new));
    let mut grain = STRIPE_GRAIN.with(Cell::get);
    let mut lost = false;
    // Claim one stripe with the witnessing CAS on the cursor.
    let (start, end) = loop {
        if !rs.in_flight() || rs.old != root as u64 {
            STRIPE_GRAIN.with(|g| g.set(grain));
            return;
        }
        let c = rs.cursor as usize;
        if c >= len {
            STRIPE_GRAIN.with(|g| g.set(grain));
            return; // fully claimed; stragglers still copying
        }
        let end = (c + grain).min(len);
        match e.resize_cell().compare_exchange(
            rs,
            ResizeState {
                cursor: end as u64,
                ..rs
            },
        ) {
            Ok(_) => {
                if !lost {
                    // Uncontended: take bigger bites next time.
                    grain = (grain * 2).min(MAX_STRIPE);
                }
                STRIPE_GRAIN.with(|g| g.set(grain));
                match dir {
                    Direction::Grow => {
                        crate::counter!(ResizeStripeClaim);
                    }
                    Direction::Shrink => {
                        crate::counter!(ResizeShrinkStripeClaim);
                    }
                }
                // A kill here is the dead-claimant scenario: the cursor
                // has advanced past a stripe nobody will copy.
                // `finish_resizes`'s sweep re-covers it.
                crate::failpoint!(ResizeStripeClaim);
                break (c, end);
            }
            Err(w) => {
                // Contended cursor: finer slices waste less double-copy.
                lost = true;
                grain = (grain / 2).max(MIN_STRIPE);
                rs = w;
            }
        }
    };
    for idx in start..end {
        migrate_bucket(e, old, idx, new, dir);
    }
}

/// Drive any in-flight migration to completion — the cooperative helper
/// for maintenance threads, drops, and tests; normal updates migrate one
/// stripe at a time. Requires the caller's pin.
///
/// Stall-proof: once the cursor is exhausted, this does not merely wait
/// for stragglers — it *sweeps* every not-yet-DONE bucket itself. A
/// claimant that died after advancing the cursor (so its stripe was
/// claimed but never copied) would otherwise leave `migrated < len`
/// forever with no helper able to reach the gap; `migrate_bucket` is
/// idempotent (FROZEN takeover + DONE election), so re-covering a live
/// straggler's stripe is harmless.
pub fn finish_resizes<E: ResizeTable>(e: &E) {
    let mut bo = None;
    loop {
        let rs = e.resize_cell().load();
        if !rs.in_flight() {
            return;
        }
        help_resize(e);
        let root = e.root_cell().load(P::ACQUIRE);
        if rs.old == root as u64 {
            // SAFETY: old == root — live under our pin.
            let old = unsafe { &*root };
            if rs.cursor as usize >= E::len_of(old) {
                // Cursor exhausted but descriptor still published:
                // re-cover any stripe whose claimant went missing.
                // SAFETY: the descriptor matched the root when loaded;
                // `new` is the live destination under our pin (it cannot
                // be retired while `old` is root).
                let new = unsafe { &*(rs.new as *const E::Table) };
                let dir = direction(E::len_of(old), E::len_of(new));
                for idx in 0..E::len_of(old) {
                    migrate_bucket(e, old, idx, new, dir);
                }
            }
        }
        snooze_lazy(&mut bo);
    }
}

/// An update ran out of patience with a FROZEN bucket: locate the
/// in-flight descriptor and help copy that one bucket out (idempotent
/// takeover via `migrate_bucket`). No-op when the descriptor moved on —
/// the bucket's DONE transition is then already imminent or published.
/// Requires the caller's pin.
pub fn help_frozen_bucket<E: ResizeTable>(e: &E, t: &E::Table, idx: usize) {
    let rs = e.resize_cell().load();
    let tp = t as *const E::Table as u64;
    if !rs.in_flight() || rs.old != tp || e.root_cell().load(P::ACQUIRE) as u64 != tp {
        return;
    }
    crate::counter!(ResizeTakeover);
    // SAFETY: the descriptor matches the live root — `new` is the live
    // destination under the caller's pin.
    let new = unsafe { &*(rs.new as *const E::Table) };
    let dir = direction(E::len_of(t), E::len_of(new));
    migrate_bucket(e, t, idx, new, dir);
}

/// Count an update's wait on a FROZEN bucket, labeled by the in-flight
/// direction (telemetry builds only — the descriptor probe compiles out
/// otherwise).
pub fn note_frozen_wait<E: ResizeTable>(e: &E, t: &E::Table) {
    #[cfg(feature = "telemetry")]
    {
        match frozen_wait_direction(e, t) {
            Direction::Grow => {
                crate::counter!(ResizeFrozenWait);
            }
            Direction::Shrink => {
                crate::counter!(ResizeShrinkFrozenWait);
            }
        }
    }
    #[cfg(not(feature = "telemetry"))]
    {
        let _ = (e, t);
    }
}

#[cfg(feature = "telemetry")]
fn frozen_wait_direction<E: ResizeTable>(e: &E, t: &E::Table) -> Direction {
    let rs = e.resize_cell().load();
    let tp = t as *const E::Table as u64;
    if rs.in_flight() && rs.old == tp && e.root_cell().load(P::ACQUIRE) as u64 == tp {
        // SAFETY: descriptor matches the live root — `new` is the live
        // destination under the caller's pin.
        let new = unsafe { &*(rs.new as *const E::Table) };
        return direction(E::len_of(t), E::len_of(new));
    }
    // Descriptor moved on (the wait is about to resolve): attribute to
    // the common direction.
    Direction::Grow
}

/// Seal-and-copy one source bucket into `new`. The seal-CAS winner is
/// the *preferred* copier (updates landing on the FROZEN window wait
/// briefly; finds read the frozen content in place) — but not the only
/// one allowed: a FROZEN bucket whose copier stalled or died is copied
/// again by any helper. The copy is idempotent (`copy_image` is
/// CAS-if-absent over the immutable frozen image), the census handshake
/// keeps every copy write pre-DONE, and the CLOSING→DONE CAS elects
/// exactly one winner, which alone retires the chain and accounts the
/// bucket — so a dead copier delays this bucket, never wedges it.
fn migrate_bucket<E: ResizeTable>(
    e: &E,
    old: &E::Table,
    idx: usize,
    new: &E::Table,
    dir: Direction,
) {
    let mut img = E::load_bucket(old, idx);
    let mut bo = None;
    loop {
        if E::is_done(img) {
            // Already migrated and accounted (re-entry via
            // finish_resizes or the sweep).
            return;
        }
        if E::is_frozen(img) {
            // Takeover: the sealing copier may be stalled or dead.
            if copy_frozen(e, old, idx, img, new) {
                break; // our DONE transition: account below
            }
            return; // a rival's DONE transition accounted already
        }
        if E::is_closing(img) {
            // Copy complete; a publisher died (or is racing us) between
            // CLOSING and DONE. Drain stragglers and race the transition
            // ourselves.
            if publish_done(e, old, idx, img) {
                break;
            }
            return;
        }
        if E::is_empty_img(img) {
            // Empty source: seal straight to DONE.
            match E::cas_bucket(old, idx, img, E::done_img()) {
                Ok(()) => break,
                Err(w) => {
                    img = w;
                    snooze_lazy(&mut bo);
                }
            }
            continue;
        }
        // Freeze the content: one-way — updates now wait, finds still
        // read the (authoritative, immutable) frozen image.
        match E::cas_bucket(old, idx, img, E::sealed(img)) {
            Ok(()) => {
                // A kill here leaves the bucket FROZEN with no copier —
                // the takeover arm above must recover it.
                crate::failpoint!(ResizeSealFrozen);
                if copy_frozen(e, old, idx, E::sealed(img), new) {
                    break;
                }
                return; // a takeover helper beat us to DONE
            }
            Err(w) => {
                img = w;
                snooze_lazy(&mut bo);
            }
        }
    }
    // Exactly one DONE transition per bucket reports it migrated.
    match dir {
        Direction::Grow => {
            crate::counter!(ResizeBucketMigrate);
        }
        Direction::Shrink => {
            crate::counter!(ResizeShrinkBucketMigrate);
        }
    }
    // Ordering: ACQREL — the finisher's promotion happens-after every
    // copier's DONE publication.
    if E::migrated_of(old).fetch_add(1, P::ACQREL) + 1 == E::len_of(old) {
        finish_resize(e, old, dir);
    }
}

/// Copy a FROZEN bucket's (immutable) image into the destination and
/// race it through CLOSING to DONE. Returns whether *we* won the DONE
/// transition — the winner alone retires the drained chain and must
/// account the bucket.
///
/// Safe to run concurrently with the sealing copier or any number of
/// takeover helpers: `copy_image` is CAS-if-absent over the same
/// immutable image, and the [`census`](super::census) handshake
/// guarantees no copier's destination write can land after DONE — we
/// announce, re-validate the bucket is still exactly FROZEN (standing
/// down if the window closed), copy, and clear the announcement before
/// anyone may publish DONE.
fn copy_frozen<E: ResizeTable>(
    e: &E,
    old: &E::Table,
    idx: usize,
    frozen: E::Image,
    new: &E::Table,
) -> bool {
    debug_assert!(E::is_frozen(frozen), "copy_frozen on an unsealed bucket");
    let addr = E::bucket_addr(old, idx);
    {
        let _census = census::announce(addr);
        // Re-validate post-announce (the Dekker edge — see the census
        // module docs): if the bucket left FROZEN after our
        // announcement, the publisher's scan may have missed us, so we
        // must not write. The image is immutable, so any change means
        // CLOSING or DONE.
        if E::load_bucket(old, idx) == frozen {
            e.copy_image(new, frozen);
        }
        // Guard dropped here: our destination writes are complete and
        // visible before any publisher's scan can miss us.
    }
    // Close the copier window. One CAS winner; losers fall through to
    // the publish race on the same (deterministic) image.
    let closing = E::closing_of(frozen);
    let _ = E::cas_bucket(old, idx, frozen, closing);
    publish_done(e, old, idx, closing)
}

/// Drain straggling copiers off a CLOSING bucket, then race its
/// CLOSING→DONE transition. Returns whether *we* won — the winner alone
/// retires the drained chain.
fn publish_done<E: ResizeTable>(e: &E, old: &E::Table, idx: usize, closing: E::Image) -> bool {
    debug_assert!(E::is_closing(closing), "publish_done on a non-CLOSING image");
    let addr = E::bucket_addr(old, idx);
    // Wait until no rival copier still announces this bucket: a live one
    // finishes its (chain-length-bounded) copy and clears; a killed
    // one's guard cleared on unwind. This wait is the fence that keeps
    // every copy write pre-DONE.
    let mut bo = None;
    while census::rivals(addr) {
        snooze_lazy(&mut bo);
    }
    // Publish DONE — the linearization point after which this bucket's
    // keys live in the destination. A kill *before* the CAS re-opens the
    // publish window (any helper re-runs this phase); after a successful
    // CAS the accounting in `migrate_bucket` is fault-free by
    // construction (no failpoints between the transition and the
    // migrated increment).
    crate::failpoint!(ResizePublishDone);
    if E::cas_bucket(old, idx, closing, E::done_img()).is_err() {
        return false; // a rival published DONE (the image is immutable)
    }
    // Retire the drained chain — winner only, exactly once per bucket.
    // SAFETY: we are the unique DONE winner; the CAS just unlinked the
    // image's chain.
    unsafe { e.retire_image(closing) };
    true
}

/// Run by the unique copier whose DONE transition drained the last
/// bucket: promote the destination, clear the descriptor, retire the
/// source, and account the completed migration to its direction's
/// generation counter.
fn finish_resize<E: ResizeTable>(e: &E, old: &E::Table, dir: Direction) {
    let rs = e.resize_cell().load();
    let op = old as *const E::Table as *mut E::Table;
    debug_assert!(rs.in_flight() && rs.old == op as u64, "finisher raced the descriptor");
    let new = rs.new as *mut E::Table;
    // Ordering: ACQREL CAS — the release half publishes the fully
    // populated destination to readers' ACQUIRE root loads.
    let swung = e
        .root_cell()
        .compare_exchange(op, new, P::ACQREL, P::ACQUIRE)
        .is_ok();
    debug_assert!(swung, "root moved before the finisher");
    // Clear the descriptor only after the root swing so `table_after`'s
    // descriptor-matches-root rule stays sound.
    let mut cur = rs;
    while cur.in_flight() && cur.old == op as u64 {
        match e.resize_cell().compare_exchange(cur, ResizeState::default()) {
            Ok(_) => break,
            Err(w) => cur = w,
        }
    }
    // Ordering: ACQREL — generation reads observe a promoted root.
    match dir {
        Direction::Grow => {
            e.grow_cell().fetch_add(1, P::ACQREL);
            crate::counter!(ResizeFinish);
        }
        Direction::Shrink => {
            e.shrink_cell().fetch_add(1, P::ACQREL);
            crate::counter!(ResizeShrinkFinish);
        }
    }
    // Retire the drained generation — bucket array and all (every bucket
    // holds a DONE seal; chains were retired at their DONE transitions).
    // Pinned readers mid-fall-through keep it alive: the region
    // guarantee of the table's scheme.
    // SAFETY: unlinked from both the root and the descriptor; unique.
    unsafe { e.retire_drained_table(op) };
}

// ---------------------------------------------------------------------------
// Background convergence
// ---------------------------------------------------------------------------

/// One maintenance pass over a table: evaluate the shrink trigger and
/// drive any in-flight migration to completion, with zero foreground
/// operations required. Implemented by both hash tables (pin, call
/// [`try_begin_shrink`], then their `finish_resizes`).
pub trait Maintain: Send + Sync {
    /// Run one pass; returns `true` when the table is idle (no
    /// descriptor in flight) on return.
    fn maintain(&self) -> bool;
}

/// A maintenance thread that periodically runs [`Maintain::maintain`] on
/// a set of tables, so a quiescent half-migrated table converges — and a
/// quiescent drained table shrinks — without foreground traffic.
///
/// Each pass runs under `catch_unwind` (the chaos suite kills copiers
/// *inside* maintenance passes; the next pass recovers idempotently), so
/// the migrator itself survives an injected death mid-`finish_resizes`.
/// Dropping the handle stops and joins the thread.
pub struct BackgroundMigrator {
    stop: Arc<AtomicBool>,
    panics: Arc<AtomicUsize>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl BackgroundMigrator {
    /// Spawn the migrator over `tables`, running a full pass every
    /// `interval`.
    pub fn spawn(tables: Vec<Arc<dyn Maintain>>, interval: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let panics = Arc::new(AtomicUsize::new(0));
        let (flag, deaths) = (Arc::clone(&stop), Arc::clone(&panics));
        let handle = std::thread::Builder::new()
            .name("resize-migrator".into())
            .spawn(move || {
                // Ordering: Acquire — pairs with the Release in `stop`.
                while !flag.load(Ordering::Acquire) {
                    for t in &tables {
                        if catch_unwind(AssertUnwindSafe(|| t.maintain())).is_err() {
                            // An injected (or real) death mid-pass: the
                            // protocol is takeover-safe, the next pass
                            // re-covers whatever this one abandoned.
                            deaths.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    // Sleep in short slices so `stop` stays prompt.
                    let mut left = interval;
                    while !left.is_zero() && !flag.load(Ordering::Acquire) {
                        let nap = left.min(Duration::from_millis(1));
                        std::thread::sleep(nap);
                        left -= nap;
                    }
                }
            })
            .expect("spawn resize-migrator thread");
        Self {
            stop,
            panics,
            handle: Some(handle),
        }
    }

    /// Maintenance passes that died by panic (fault-injection kills).
    pub fn panics(&self) -> usize {
        self.panics.load(Ordering::Relaxed)
    }

    /// Stop and join the migrator thread (also runs on drop).
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        // Ordering: Release — pairs with the Acquire in the thread loop.
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for BackgroundMigrator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::{CacheHash, Chaining, ConcurrentMap, LinkVal};
    use crate::atomics::CachedMemEff;

    #[test]
    fn test_hysteresis_band_is_at_least_4x_each_way() {
        // The no-oscillation argument needs the two thresholds separated
        // by a multiplicative churn band ≥ 4 in both directions (see the
        // module docs); a future constant tweak must not silently close
        // it.
        assert!(GROW_LOAD_FACTOR * SHRINK_FACTOR >= 4);
        assert!(SHRINK_FACTOR >= 2 * 1, "post-shrink LF must stay below grow trigger");
        assert!(MIN_STRIPE >= 1 && MIN_STRIPE <= MIGRATION_STRIPE);
        assert!(MAX_STRIPE >= MIGRATION_STRIPE);
    }

    #[test]
    fn test_stripe_grain_starts_at_default() {
        assert_eq!(stripe_grain(), MIGRATION_STRIPE);
    }

    #[test]
    fn test_quiescent_drained_table_shrinks_via_maintain() {
        // Build undersized (floor 2), grow by inserts, drain, then let
        // maintain() alone return the memory — no foreground ops.
        let t: Chaining = Chaining::new(2);
        for k in 0..4096u64 {
            assert!(t.insert(k, k));
        }
        while !t.maintain() {}
        let peak = t.capacity();
        assert!(peak >= 1024);
        for k in 0..4096u64 {
            assert!(t.remove(k));
        }
        // Converge the shrink chain: each pass publishes at most one
        // halving, so iterate until idle *and* stable.
        loop {
            let before = t.capacity();
            let idle = t.maintain();
            if idle && t.capacity() == before {
                break;
            }
        }
        assert!(t.capacity() < peak, "no memory returned: {}", t.capacity());
        assert_eq!(t.capacity(), 2, "empty table must shrink to its floor");
        assert!(t.shrink_generation() >= 1);
        // Still a working table.
        assert!(t.insert(7, 70));
        assert_eq!(t.find(7), Some(70));
    }

    #[test]
    fn test_shrink_respects_construction_floor() {
        // A table built at 256 and fully drained must NOT shrink below
        // 256 — the user asked for that capacity.
        let t: CacheHash<CachedMemEff<LinkVal>> = CacheHash::new(256);
        for k in 0..100u64 {
            assert!(t.insert(k, k));
        }
        for k in 0..100u64 {
            assert!(t.remove(k));
        }
        for _ in 0..8 {
            t.maintain();
        }
        assert_eq!(t.capacity(), 256);
        assert_eq!(t.shrink_generation(), 0);
    }

    #[test]
    fn test_background_migrator_stops_cleanly() {
        let t: std::sync::Arc<Chaining> = std::sync::Arc::new(Chaining::new(16));
        for k in 0..8u64 {
            t.insert(k, k);
        }
        let mig = BackgroundMigrator::spawn(
            vec![std::sync::Arc::clone(&t) as Arc<dyn Maintain>],
            Duration::from_millis(1),
        );
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(mig.panics(), 0);
        mig.stop(); // joins; must not hang or panic
        assert_eq!(t.find(3), Some(3));
    }
}
