//! Concurrent hash tables (paper §4/§5.2/§5.3).
//!
//! * [`CacheHash`] — the paper's table: separate chaining with the first
//!   link **inlined into the bucket as a big atomic**, generic over the
//!   big-atomic strategy (the §5.2 sweep).
//! * [`Chaining`] — identical algorithm without inlining (bucket is a
//!   pointer): the paper's baseline.
//! * [`ShardedLockMap`], [`GlobalLockMap`] — comparator stand-ins for the
//!   §5.3 open-source tables (DESIGN.md §Substitutions).
//!
//! All expose [`ConcurrentMap`] over 8-byte keys/values (what §5.2/§5.3
//! measure).

pub mod cachehash;
pub mod chaining;
pub mod globallock;
pub mod shardlock;

pub use cachehash::{CacheHash, LinkVal};
pub use chaining::Chaining;
pub use globallock::GlobalLockMap;
pub use shardlock::ShardedLockMap;

use crate::util::rng::mix64;

/// The uniform map interface the benchmarks drive.
///
/// `insert` is insert-if-absent (returns false when the key is present);
/// `remove` returns whether the key was present — the semantics of the
/// paper's benchmark loop ("randomly performs a find, insert, or delete").
pub trait ConcurrentMap: Send + Sync {
    fn find(&self, key: u64) -> Option<u64>;
    fn insert(&self, key: u64, value: u64) -> bool;
    fn remove(&self, key: u64) -> bool;
    /// Implementation label for report rows.
    fn map_name(&self) -> &'static str;
}

/// Bucket index for `key` in a power-of-two table of size `n`.
#[inline]
pub fn bucket_of(key: u64, n: usize) -> usize {
    debug_assert!(n.is_power_of_two());
    (mix64(key) as usize) & (n - 1)
}

/// Round a requested capacity up to a power of two (load factor one,
/// "size rounded to the next power of two" — §5.2).
pub fn table_capacity(n: usize) -> usize {
    n.next_power_of_two().max(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_bucket_of_in_range_and_spread() {
        let n = 1024;
        let mut counts = vec![0usize; n];
        for k in 0..(n as u64 * 8) {
            let b = bucket_of(k, n);
            assert!(b < n);
            counts[b] += 1;
        }
        // mix64 spreads sequential keys: no bucket more than 4x the mean.
        assert!(counts.iter().all(|&c| c <= 32));
    }

    #[test]
    fn test_table_capacity() {
        assert_eq!(table_capacity(1), 2);
        assert_eq!(table_capacity(1000), 1024);
        assert_eq!(table_capacity(1024), 1024);
        assert_eq!(table_capacity(1025), 2048);
    }
}
