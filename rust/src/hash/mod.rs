//! Concurrent hash tables (paper §4/§5.2/§5.3), generic over
//! arbitrary-length keys and values.
//!
//! * [`CacheHash`] — the paper's table: separate chaining with the first
//!   link **inlined into the bucket as a big atomic**, generic over the
//!   big-atomic strategy (the §5.2 sweep) *and* over the key/value
//!   types (the §5.3 arbitrary-length comparison).
//! * [`Chaining`] — identical algorithm without inlining (bucket is a
//!   pointer): the paper's baseline.
//! * [`ShardedLockMap`], [`GlobalLockMap`] — comparator stand-ins for the
//!   §5.3 open-source tables (DESIGN.md §Substitutions).
//!
//! `CacheHash` and `Chaining` **resize online in both directions**
//! through ONE shared protocol, the [`resize`] engine: a descriptor
//! ([`ResizeState`]) published through a big atomic names the source
//! and destination tables, helpers claim migration stripes with the
//! witnessing `compare_exchange` on its cursor (adapting their stripe
//! grain to contention), and each source bucket is sealed
//! FROZEN → CLOSING → DONE with census-fenced copier takeover, while
//! `find` stays lock-free throughout — it reads sealed-but-uncopied
//! buckets in place and falls through DONE marks old→new.  Drained
//! tables and migrated chain links are reclaimed through the epoch
//! scheme (`S: RegionSmr`).
//!
//! The protocol is direction-agnostic; only the *triggers* differ:
//!
//! * **Grow** — a per-stripe occupancy estimate crossing the growth
//!   load factor publishes a double-size destination.
//! * **Shrink** — occupancy falling below the hysteresis band (see
//!   [`resize`] for the no-oscillation argument) publishes a half-size
//!   destination, bounded below by the construction-time capacity.
//!
//! Updates help migrate incrementally; a quiescent half-migrated table
//! converges through [`Maintain::maintain`], driven manually or by a
//! [`BackgroundMigrator`] thread.  The per-table code contributes only
//! its bucket word/link encoding and copy routine (the
//! [`resize::ResizeTable`] contract); everything else lives once in the
//! engine.
//!
//! All expose [`ConcurrentMap<K, V>`] for any
//! [`AtomicValue`](crate::atomics::AtomicValue) key/value — `u64 → u64`
//! (what §5.2 measures) is the default instantiation, and
//! `Words<4> → Words<4>` style tables reproduce §5.3's multi-word rows:
//!
//! ```
//! use big_atomics::atomics::{CachedMemEff, Words};
//! use big_atomics::hash::{CacheHash, ConcurrentMap, Link};
//!
//! type K = Words<4>;
//! type V = Words<4>;
//! let t: CacheHash<CachedMemEff<Link<K, V>>, K, V> = CacheHash::new(64);
//! assert!(t.insert(Words([1; 4]), Words([9; 4])));
//! assert_eq!(t.find(Words([1; 4])), Some(Words([9; 4])));
//! assert!(t.remove(Words([1; 4])));
//! ```

pub mod cachehash;
pub(crate) mod census;
pub mod chaining;
pub mod globallock;
pub mod resize;
pub mod shardlock;

pub use cachehash::{CacheHash, Link, LinkVal};
pub use chaining::Chaining;
pub use globallock::GlobalLockMap;
pub use resize::{BackgroundMigrator, Maintain};
pub use shardlock::ShardedLockMap;

use crate::atomics::AtomicValue;
use crate::util::rng::mix64;

/// The uniform map interface the benchmarks drive, generic over key and
/// value types (`u64 → u64` by default, matching the §5.2 benchmarks).
///
/// `insert` is insert-if-absent (returns false when the key is present);
/// `remove` returns whether the key was present — the semantics of the
/// paper's benchmark loop ("randomly performs a find, insert, or delete").
pub trait ConcurrentMap<K: AtomicValue = u64, V: AtomicValue = u64>: Send + Sync {
    fn find(&self, key: K) -> Option<V>;
    fn insert(&self, key: K, value: V) -> bool;
    fn remove(&self, key: K) -> bool;
    /// Implementation label for report rows.
    fn map_name(&self) -> &'static str;
    /// Bucket count of the live table — grows across online resizes for
    /// [`CacheHash`]/[`Chaining`], fixed for the lock-based stand-ins.
    fn capacity(&self) -> usize;
    /// Estimated live-entry count: the stripe-counter sum on the
    /// lock-free tables (approximate under concurrent updates and
    /// mid-migration), exact for the lock-based stand-ins.
    fn occupancy(&self) -> usize;
    /// Completed shrink migrations (capacity halvings that returned
    /// memory).  Zero for tables that never shrink (the lock-based
    /// stand-ins keep the default).
    fn shrink_generation(&self) -> usize {
        0
    }
}

/// Descriptor of an in-flight incremental table resize, published
/// through a big atomic (the tables use a [`SeqLock`](crate::atomics::SeqLock)
/// over it): the old (source) and new (destination) table addresses plus
/// the stripe-claim cursor.  All-zero ⇔ idle.  Helpers claim migration
/// stripes by advancing `cursor` with the witnessing
/// `compare_exchange`; a descriptor is only acted on while `old` equals
/// the map's live root table (a stale descriptor — possible in the
/// publish/retract window of a lost growth race — matches no root and is
/// therefore inert).
#[repr(C, align(8))]
#[derive(Copy, Clone, PartialEq, Default, Debug)]
pub struct ResizeState {
    /// Address of the table being drained (0 when idle).
    pub old: u64,
    /// Address of the destination table (0 when idle).
    pub new: u64,
    /// Next unclaimed source-bucket index.
    pub cursor: u64,
}

// SAFETY: repr(C), three u64 words — no padding, align 8, bitwise Eq.
unsafe impl AtomicValue for ResizeState {}

impl ResizeState {
    /// Is a migration in flight?
    #[inline]
    pub fn in_flight(&self) -> bool {
        self.new != 0
    }
}

/// Word-fold hash of any [`AtomicValue`]: mixes each 64-bit word of the
/// representation. Bitwise-equal values (the `AtomicValue` equality
/// contract) hash equal; for a single word this is exactly
/// [`mix64`]`(word)`.
#[inline]
pub fn hash_value<K: AtomicValue>(key: &K) -> u64 {
    let p = key as *const K as *const u64;
    let mut h = 0u64;
    for i in 0..K::WORDS {
        // SAFETY: AtomicValue guarantees K is K::WORDS initialized
        // 8-byte-aligned words of plain old data.
        h = mix64(h ^ unsafe { p.add(i).read() });
    }
    h
}

/// Bucket index for `key` in a power-of-two table of size `n`.
#[inline]
pub fn bucket_for<K: AtomicValue>(key: &K, n: usize) -> usize {
    debug_assert!(n.is_power_of_two());
    (hash_value(key) as usize) & (n - 1)
}

/// Single-word convenience form of [`bucket_for`].
#[inline]
pub fn bucket_of(key: u64, n: usize) -> usize {
    bucket_for(&key, n)
}

/// Round a requested capacity up to a power of two (load factor one,
/// "size rounded to the next power of two" — §5.2).
pub fn table_capacity(n: usize) -> usize {
    n.next_power_of_two().max(2)
}

/// Hash-map key adapter for the lock-based comparators: `Hash`/`Eq` over
/// an [`AtomicValue`]'s bits (the contract makes `PartialEq` a bitwise
/// equivalence, so the manual `Eq` and the word hash agree).
pub(crate) struct BitsKey<K: AtomicValue>(pub K);

impl<K: AtomicValue> PartialEq for BitsKey<K> {
    fn eq(&self, other: &Self) -> bool {
        self.0 == other.0
    }
}

impl<K: AtomicValue> Eq for BitsKey<K> {}

impl<K: AtomicValue> std::hash::Hash for BitsKey<K> {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_u64(hash_value(&self.0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;

    #[test]
    fn test_bucket_of_in_range_and_spread() {
        let n = 1024;
        let mut counts = vec![0usize; n];
        for k in 0..(n as u64 * 8) {
            let b = bucket_of(k, n);
            assert!(b < n);
            counts[b] += 1;
        }
        // mix64 spreads sequential keys: no bucket more than 4x the mean.
        assert!(counts.iter().all(|&c| c <= 32));
    }

    #[test]
    fn test_hash_value_single_word_matches_mix64() {
        for k in [0u64, 1, 42, u64::MAX] {
            assert_eq!(hash_value(&k), mix64(k));
        }
    }

    #[test]
    fn test_multiword_keys_spread_and_agree_with_eq() {
        let n = 1024;
        let mut counts = vec![0usize; n];
        for k in 0..(n as u64 * 8) {
            // Low-entropy multi-word keys (only word 2 varies).
            let key = Words([0, 0, k, 0]);
            let b = bucket_for(&key, n);
            assert!(b < n);
            counts[b] += 1;
        }
        assert!(counts.iter().all(|&c| c <= 32));
        assert_eq!(
            hash_value(&Words([1, 2, 3])),
            hash_value(&Words([1, 2, 3]))
        );
        assert_ne!(
            hash_value(&Words([1, 2, 3])),
            hash_value(&Words([3, 2, 1]))
        );
    }

    #[test]
    fn test_table_capacity() {
        assert_eq!(table_capacity(1), 2);
        assert_eq!(table_capacity(1000), 1024);
        assert_eq!(table_capacity(1024), 1024);
        assert_eq!(table_capacity(1025), 2048);
    }
}
