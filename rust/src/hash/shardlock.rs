//! `ShardedLockMap` — stand-in for the §5.3 open-source comparators
//! (TBB / Folly / Boost / libcuckoo families): the canonical generic
//! design of a growable concurrent map, per-shard reader-writer locks
//! over a conventional hash map.  See DESIGN.md §Substitutions.
//! Generic over the same key/value types as the big-atomic tables.

use std::collections::HashMap;
use std::sync::RwLock;

use super::{hash_value, BitsKey, ConcurrentMap};
use crate::atomics::AtomicValue;

pub struct ShardedLockMap<K: AtomicValue = u64, V: AtomicValue = u64> {
    shards: Vec<RwLock<HashMap<BitsKey<K>, V>>>,
    mask: usize,
}

impl<K: AtomicValue, V: AtomicValue> ShardedLockMap<K, V> {
    /// `n` expected entries spread over `shards` (rounded to a power of
    /// two; the comparators typically use ~4x the thread count).
    pub fn new(n: usize, shards: usize) -> Self {
        let count = shards.next_power_of_two().max(2);
        let per = (n / count).max(8);
        Self {
            shards: (0..count)
                .map(|_| RwLock::new(HashMap::with_capacity(per * 2)))
                .collect(),
            mask: count - 1,
        }
    }

    #[inline]
    fn shard(&self, key: &K) -> &RwLock<HashMap<BitsKey<K>, V>> {
        // High hash bits pick the shard; low bits pick the HashMap slot.
        &self.shards[(hash_value(key) >> 32) as usize & self.mask]
    }
}

impl<K: AtomicValue, V: AtomicValue> ConcurrentMap<K, V> for ShardedLockMap<K, V> {
    fn find(&self, key: K) -> Option<V> {
        self.shard(&key).read().unwrap().get(&BitsKey(key)).copied()
    }

    fn insert(&self, key: K, value: V) -> bool {
        let mut s = self.shard(&key).write().unwrap();
        if s.contains_key(&BitsKey(key)) {
            return false;
        }
        s.insert(BitsKey(key), value);
        true
    }

    fn remove(&self, key: K) -> bool {
        self.shard(&key).write().unwrap().remove(&BitsKey(key)).is_some()
    }

    fn map_name(&self) -> &'static str {
        "ShardedLock(os-standin)"
    }

    fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().capacity()).sum()
    }

    fn occupancy(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;
    use std::sync::Arc;

    #[test]
    fn test_basic() {
        let m: ShardedLockMap = ShardedLockMap::new(1024, 16);
        assert!(m.insert(1, 2));
        assert!(!m.insert(1, 3));
        assert_eq!(m.find(1), Some(2));
        assert!(m.remove(1));
        assert_eq!(m.find(1), None);
    }

    #[test]
    fn test_generic_multiword() {
        let m: ShardedLockMap<Words<4>, Words<4>> = ShardedLockMap::new(64, 4);
        assert!(m.insert(Words([1, 2, 3, 4]), Words([5; 4])));
        assert!(!m.insert(Words([1, 2, 3, 4]), Words([6; 4])));
        assert_eq!(m.find(Words([1, 2, 3, 4])), Some(Words([5; 4])));
        assert!(m.remove(Words([1, 2, 3, 4])));
        assert_eq!(m.find(Words([1, 2, 3, 4])), None);
    }

    #[test]
    fn test_concurrent() {
        let m: Arc<ShardedLockMap> = Arc::new(ShardedLockMap::new(4096, 8));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    let base = t as u64 * 1_000_000;
                    for i in 0..2_000u64 {
                        assert!(m.insert(base + i, i));
                        assert_eq!(m.find(base + i), Some(i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
