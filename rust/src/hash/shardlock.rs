//! `ShardedLockMap` — stand-in for the §5.3 open-source comparators
//! (TBB / Folly / Boost / libcuckoo families): the canonical generic
//! design of a growable concurrent map, per-shard reader-writer locks
//! over a conventional hash map.  See DESIGN.md §Substitutions.

use std::collections::HashMap;
use std::sync::RwLock;

use super::ConcurrentMap;
use crate::util::rng::mix64;

pub struct ShardedLockMap {
    shards: Vec<RwLock<HashMap<u64, u64>>>,
    mask: usize,
}

impl ShardedLockMap {
    /// `n` expected entries spread over `shards` (rounded to a power of
    /// two; the comparators typically use ~4x the thread count).
    pub fn new(n: usize, shards: usize) -> Self {
        let count = shards.next_power_of_two().max(2);
        let per = (n / count).max(8);
        Self {
            shards: (0..count)
                .map(|_| RwLock::new(HashMap::with_capacity(per * 2)))
                .collect(),
            mask: count - 1,
        }
    }

    #[inline]
    fn shard(&self, key: u64) -> &RwLock<HashMap<u64, u64>> {
        &self.shards[(mix64(key) as usize >> 32) & self.mask]
    }
}

impl ConcurrentMap for ShardedLockMap {
    fn find(&self, key: u64) -> Option<u64> {
        self.shard(key).read().unwrap().get(&key).copied()
    }

    fn insert(&self, key: u64, value: u64) -> bool {
        let mut s = self.shard(key).write().unwrap();
        if s.contains_key(&key) {
            return false;
        }
        s.insert(key, value);
        true
    }

    fn remove(&self, key: u64) -> bool {
        self.shard(key).write().unwrap().remove(&key).is_some()
    }

    fn map_name(&self) -> &'static str {
        "ShardedLock(os-standin)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn test_basic() {
        let m = ShardedLockMap::new(1024, 16);
        assert!(m.insert(1, 2));
        assert!(!m.insert(1, 3));
        assert_eq!(m.find(1), Some(2));
        assert!(m.remove(1));
        assert_eq!(m.find(1), None);
    }

    #[test]
    fn test_concurrent() {
        let m = Arc::new(ShardedLockMap::new(4096, 8));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    let base = t as u64 * 1_000_000;
                    for i in 0..2_000u64 {
                        assert!(m.insert(base + i, i));
                        assert_eq!(m.find(base + i), Some(i));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
