//! `GlobalLockMap` — single-mutex map: the §5.3 comparison's floor
//! (what a non-concurrent library wrapped in a lock looks like).
//! Generic over the same key/value types as the big-atomic tables.

use std::collections::HashMap;
use std::sync::Mutex;

use super::{BitsKey, ConcurrentMap};
use crate::atomics::AtomicValue;

pub struct GlobalLockMap<K: AtomicValue = u64, V: AtomicValue = u64> {
    inner: Mutex<HashMap<BitsKey<K>, V>>,
}

impl<K: AtomicValue, V: AtomicValue> GlobalLockMap<K, V> {
    pub fn new(n: usize) -> Self {
        Self {
            inner: Mutex::new(HashMap::with_capacity(n * 2)),
        }
    }
}

impl<K: AtomicValue, V: AtomicValue> ConcurrentMap<K, V> for GlobalLockMap<K, V> {
    fn find(&self, key: K) -> Option<V> {
        self.inner.lock().unwrap().get(&BitsKey(key)).copied()
    }

    fn insert(&self, key: K, value: V) -> bool {
        let mut m = self.inner.lock().unwrap();
        if m.contains_key(&BitsKey(key)) {
            return false;
        }
        m.insert(BitsKey(key), value);
        true
    }

    fn remove(&self, key: K) -> bool {
        self.inner.lock().unwrap().remove(&BitsKey(key)).is_some()
    }

    fn map_name(&self) -> &'static str {
        "GlobalLock(floor)"
    }

    fn capacity(&self) -> usize {
        self.inner.lock().unwrap().capacity()
    }

    fn occupancy(&self) -> usize {
        self.inner.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::atomics::Words;

    #[test]
    fn test_basic() {
        let m: GlobalLockMap = GlobalLockMap::new(16);
        assert!(m.insert(9, 90));
        assert!(!m.insert(9, 91));
        assert_eq!(m.find(9), Some(90));
        assert!(m.remove(9));
        assert!(!m.remove(9));
    }

    #[test]
    fn test_generic_multiword() {
        let m: GlobalLockMap<Words<2>, u64> = GlobalLockMap::new(16);
        assert!(m.insert(Words([7, 8]), 1));
        assert_eq!(m.find(Words([7, 8])), Some(1));
        assert_eq!(m.find(Words([8, 7])), None);
        assert!(m.remove(Words([7, 8])));
    }
}
