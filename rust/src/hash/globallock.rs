//! `GlobalLockMap` — single-mutex map: the §5.3 comparison's floor
//! (what a non-concurrent library wrapped in a lock looks like).

use std::collections::HashMap;
use std::sync::Mutex;

use super::ConcurrentMap;

pub struct GlobalLockMap {
    inner: Mutex<HashMap<u64, u64>>,
}

impl GlobalLockMap {
    pub fn new(n: usize) -> Self {
        Self {
            inner: Mutex::new(HashMap::with_capacity(n * 2)),
        }
    }
}

impl ConcurrentMap for GlobalLockMap {
    fn find(&self, key: u64) -> Option<u64> {
        self.inner.lock().unwrap().get(&key).copied()
    }

    fn insert(&self, key: u64, value: u64) -> bool {
        let mut m = self.inner.lock().unwrap();
        if m.contains_key(&key) {
            return false;
        }
        m.insert(key, value);
        true
    }

    fn remove(&self, key: u64) -> bool {
        self.inner.lock().unwrap().remove(&key).is_some()
    }

    fn map_name(&self) -> &'static str {
        "GlobalLock(floor)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_basic() {
        let m = GlobalLockMap::new(16);
        assert!(m.insert(9, 90));
        assert!(!m.insert(9, 91));
        assert_eq!(m.find(9), Some(90));
        assert!(m.remove(9));
        assert!(!m.remove(9));
    }
}
